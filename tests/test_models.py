"""Per-architecture smoke tests (assignment requirement): reduced configs of
every family run one forward + one federated train step on CPU, asserting
output shapes and no NaNs. Plus decode-vs-forward consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import param_count
from repro.configs.registry import ARCH_IDS, get_config, get_smoke
from repro.core import FedTopology, HierFAVGConfig, build_train_step, init_state
from repro.models import transformer
from repro.optim import sgd


def _batch_for(cfg, rng, n_clients, b, s):
    if cfg.embed_inputs:
        inputs = rng.integers(0, cfg.vocab_size, size=(n_clients, b, s)).astype(np.int32)
    else:
        inputs = rng.normal(size=(n_clients, b, s, cfg.d_model)).astype(np.float32)
    targets = rng.integers(0, cfg.vocab_size, size=(n_clients, b, s)).astype(np.int32)
    return {"inputs": jnp.asarray(inputs), "targets": jnp.asarray(targets)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_smoke(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch1 = _batch_for(cfg, rng, 1, b, s)
    one = jax.tree_util.tree_map(lambda x: x[0], batch1)
    logits, aux = transformer.forward(params, cfg, one["inputs"])
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"

    # one federated train step on the smoke topology
    topo = FedTopology(num_edges=cfg.fed.edges_per_pod, clients_per_edge=cfg.fed.clients_per_edge)
    hier = HierFAVGConfig(kappa1=cfg.fed.kappa1, kappa2=cfg.fed.kappa2)
    opt = sgd(1e-2)
    weights = jnp.ones((topo.num_clients,))
    loss_fn = transformer.make_loss_fn(cfg)
    state = init_state(jax.random.PRNGKey(1), params, opt, topo, hier)
    step = jax.jit(build_train_step(loss_fn, opt, topo, hier, weights))
    batch = _batch_for(cfg, rng, topo.num_clients, b, s)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    leaves = jax.tree_util.tree_leaves(state.params)
    assert all(not bool(jnp.any(jnp.isnan(x))) for x in leaves), f"{arch}: NaN params"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_dimensions(arch):
    """The FULL configs carry the exact assigned dimensions (never built on
    CPU — exercised via the dry-run's ShapeDtypeStructs only)."""
    cfg = get_config(arch)
    spec = {
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == spec


def test_moe_archs_exact_expert_config():
    a = get_config("arctic-480b").moe
    assert (a.num_experts, a.top_k, a.dense_residual) == (128, 2, True)
    d = get_config("deepseek-v3-671b").moe
    assert (d.num_experts, d.top_k, d.num_shared_experts) == (256, 8, 1)
    assert get_config("deepseek-v3-671b").mla is not None


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN §Arch-applicability)."""
    runners = {a for a in ARCH_IDS if get_config(a).run_long_context}
    assert runners == {"xlstm-350m", "recurrentgemma-9b"}
    for a in runners:
        assert "long_500k" in [s.name for s in get_config(a).input_shapes]
    assert "long_500k" in get_config("yi-9b").skipped_shapes


@pytest.mark.parametrize("arch", ["granite-3-2b", "yi-9b", "recurrentgemma-9b", "xlstm-350m", "deepseek-v3-smoke"])
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode (token by token through the cache) reproduces
    the full forward's logits — validates every cache implementation."""
    cfg = get_smoke(arch.replace("-smoke", "")) if not arch.endswith("smoke") else get_smoke("deepseek-v3-671b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    if cfg.embed_inputs:
        inputs = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    else:
        inputs = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    full_logits, _ = transformer.forward(params, cfg, inputs)

    caches = transformer.init_decode_caches(params, cfg, B, max_len=S)
    outs = []
    for t in range(S):
        tok = inputs[:, t]
        pos = jnp.full((B,), t, jnp.int32)
        logits, caches = transformer.decode_step(params, cfg, caches, tok, pos)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32), atol=2e-2, rtol=2e-2
    )


def test_prefill_matches_forward_last_position(rng):
    cfg = get_smoke("granite-3-2b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 10
    inputs = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    full_logits, _ = transformer.forward(params, cfg, inputs)
    pre_logits, caches = transformer.prefill(params, cfg, inputs, max_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(pre_logits), np.asarray(full_logits[:, -1]), atol=2e-3, rtol=2e-3
    )
    # continuing decode from the prefilled cache matches forward on S+1
    if cfg.embed_inputs:
        nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B,)), jnp.int32)
        ext = jnp.concatenate([inputs, nxt[:, None]], axis=1)
        full2, _ = transformer.forward(params, cfg, ext)
        logits2, _ = transformer.decode_step(
            params, cfg, caches, nxt, jnp.full((B,), S, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits2), np.asarray(full2[:, -1]), atol=2e-3, rtol=2e-3
        )


def test_param_count_matches_built_params():
    """Analytic param_count == actual leaf sizes for a smoke config."""
    for arch in ("granite-3-2b", "yi-9b", "recurrentgemma-9b"):
        cfg = get_smoke(arch)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        built = sum(x.size for x in jax.tree_util.tree_leaves(params))
        assert built == param_count(cfg), arch
