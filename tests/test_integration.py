"""End-to-end behaviour: federated CNN training reaches accuracy; the
runner's checkpoint/restore resumes exactly; failures don't derail training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import FedTopology, HierFAVGConfig, cost_model as cm
from repro.core import aggregation
from repro.data import FederatedBatcher, clustered_gaussians, make_partition
from repro.fed import FailureSimulator, FederatedRunner, RunnerConfig
from repro.models import cnn
from repro.optim import exponential_decay, sgd


def small_setup(rng, partition="edge_iid", num_samples=800):
    data = clustered_gaussians(
        rng, num_samples=num_samples, num_classes=10, dim=(12,), class_sep=4.0, noise=1.0
    )
    # edge-IID with 1-class clients needs clients_per_edge == num_classes so
    # every edge covers all classes (the paper's 10-clients-per-edge setting)
    parts = make_partition(partition, data.y, 2, 10, rng)
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=8, seed=0
    )
    # tiny MLP classifier via the cnn loss helpers
    def init(rng_key):
        k1, k2 = jax.random.split(rng_key)
        return {
            "w1": jax.random.normal(k1, (12, 32)) * 0.3,
            "b1": jnp.zeros((32,)),
            "w2": jax.random.normal(k2, (32, 10)) * 0.3,
            "b2": jnp.zeros((10,)),
        }

    def apply_fn(p, x):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def eval_fn(p):
        logits = apply_fn(p, jnp.asarray(data.x))
        return float(cnn.accuracy(logits, jnp.asarray(data.y)))

    return init, apply_fn, eval_fn, batcher, data


def make_runner(init, apply_fn, eval_fn, batcher, tmp_path=None, failures=None, rounds=30):
    topo = FedTopology(num_edges=2, clients_per_edge=10)
    hier = HierFAVGConfig(kappa1=4, kappa2=2)
    ckpt = CheckpointManager(str(tmp_path), keep=2) if tmp_path else None
    return FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(exponential_decay(0.1, 0.995, 20)),
        topology=topo,
        hier_config=hier,
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=rounds, eval_every=5, checkpoint_every=5),
        eval_fn=eval_fn,
        costs=cm.paper_workload("mnist"),
        failures=failures,
        checkpointer=ckpt,
    )


def test_federated_training_reaches_accuracy(rng):
    init, apply_fn, eval_fn, batcher, data = small_setup(rng)
    runner = make_runner(init, apply_fn, eval_fn, batcher)
    state = runner.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
    state = runner.run(state)
    accs = [h.accuracy for h in runner.history if h.accuracy is not None]
    assert accs[-1] > 0.85, f"final accuracy {accs[-1]}"
    # cost accounting is monotone in rounds
    times = [h.sim_time_s for h in runner.history]
    assert all(t2 > t1 for t1, t2 in zip(times, times[1:]))


def test_checkpoint_resume_bitexact(tmp_path, rng):
    """Run 10 rounds straight vs 5 + crash + restore + 5: identical params."""
    init, apply_fn, eval_fn, batcher, _ = small_setup(rng)
    w0 = init(jax.random.PRNGKey(1))

    r1 = make_runner(init, apply_fn, eval_fn, batcher, rounds=10)
    s1 = r1.init(jax.random.PRNGKey(0), w0)
    s1 = r1.run(s1)

    init2, apply2, eval2, batcher2, _ = small_setup(np.random.default_rng(0))
    r2 = make_runner(init2, apply_fn, eval_fn, batcher2, tmp_path=tmp_path, rounds=5)
    s2 = r2.init(jax.random.PRNGKey(0), w0)
    s2 = r2.run(s2)
    r2.checkpointer.save(int(s2.step), s2, {"round": 5, "batcher": batcher2.state_dict()})

    init3, apply3, eval3, batcher3, _ = small_setup(np.random.default_rng(0))
    r3 = make_runner(init3, apply_fn, eval_fn, batcher3, tmp_path=tmp_path, rounds=10)
    s3, start = r3.restore_or_init(jax.random.PRNGKey(0), w0)
    assert start == 5
    s3 = r3.run(s3, start_round=start)

    for a, b in zip(jax.tree_util.tree_leaves(s1.params), jax.tree_util.tree_leaves(s3.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_training_survives_failures(rng):
    """30% of clients drop at every boundary; training still converges."""
    init, apply_fn, eval_fn, batcher, _ = small_setup(rng)
    failures = FailureSimulator(20, p_fail=0.3, p_recover=0.5, seed=3)
    runner = make_runner(init, apply_fn, eval_fn, batcher, failures=failures, rounds=30)
    state = runner.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
    state = runner.run(state)
    accs = [h.accuracy for h in runner.history if h.accuracy is not None]
    assert accs[-1] > 0.8
    alive = [h.mask_alive for h in runner.history]
    assert min(alive) < 20  # failures actually happened


def test_edge_niid_converges_slower_than_edge_iid(rng):
    """The paper's qualitative claim (Fig. 4): edge-NIID hurts convergence
    relative to edge-IID at the same schedule."""
    accs = {}
    for kind in ("edge_iid", "edge_niid"):
        init, apply_fn, eval_fn, batcher, _ = small_setup(np.random.default_rng(1), kind)
        runner = make_runner(init, apply_fn, eval_fn, batcher, rounds=12)
        state = runner.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
        runner.run(state)
        accs[kind] = [h.accuracy for h in runner.history if h.accuracy is not None]
    # compare the mean accuracy across the early curve
    assert np.mean(accs["edge_iid"]) >= np.mean(accs["edge_niid"]) - 0.02
