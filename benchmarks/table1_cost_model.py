"""Paper Table I: per-iteration/per-upload latency & energy constants."""
from repro.core import cost_model as cm


def main(csv=False):
    rows = []
    for name in ("mnist", "cifar10"):
        w = cm.paper_workload(name)
        rows.append((name, w.t_comp, w.t_comm_edge, w.e_comp, w.e_comm_edge))
    print("# Table I — latency/energy constants (paper values in parens)")
    print("# expected: mnist 0.024s/0.1233s/0.0024J/0.0616J; cifar 4s/33s/0.4J/16.5J")
    for name, tc, tm, ec, em in rows:
        print(f"table1_{name},T_comp={tc:.4f}s,T_comm={tm:.4f}s,E_comp={ec:.4f}J,E_comm={em:.4f}J")
    return rows


if __name__ == "__main__":
    main()
