"""Round-time / energy distribution bench: the ``repro.sim`` replay family.

Four sections, written merge-preserving into a ``sim`` key (default
``BENCH_sim.json``):

* ``parity``      zero-variance replay vs the analytic
                  ``cloud_interval_time`` / ``cloud_interval_energy`` over
                  every paper workload x a κ grid — max relative error
                  (must sit at float64 machine precision)
* ``determinism`` the congested scenario replayed twice from fresh seeded
                  builds — percentiles must be bit-identical
* ``scenarios``   p50/p90/p99 round time + energy for the registered sim
                  scenarios, with the analytic point estimate and the
                  p99/analytic tail ratio the analytic model cannot see
* ``association`` the HFEL-style optimizer on ``hetero_clients_assoc``:
                  p99 before/after, moves, relative improvement

``--smoke`` is the CI gate: parity rel-err < 1e-12, bit-identical
determinism, and association p99_after <= p99_before, at reduced trial
counts. No hardware or jax involved — pure host numpy.
"""
from __future__ import annotations

import argparse

import numpy as np

DEFAULT_JSON = "BENCH_sim.json"

PARITY_KAPPAS = ((1, 1), (4, 2), (6, 10), (15, 4), (30, 2), (60, 1))
PARITY_TOL = 1e-12


def parity_section() -> dict:
    """Max |replay - analytic| / analytic over workloads x κ grid, plus a
    ragged-tree + compressed-transport + cluster-cost spot check."""
    from repro.core.cost_model import (
        ClusterCosts,
        cloud_interval_energy,
        cloud_interval_time,
        paper_workload,
    )
    from repro.core.hierarchy import HierarchySpec
    from repro.sim import build_round_dag, from_cluster, from_workload, simulate_round

    worst = 0.0
    worst_at = ""
    trees = {
        "uniform": HierarchySpec.uniform(5, 10),
        "ragged": HierarchySpec.from_fanouts([[16, 12, 10, 7, 5], [5]]),
    }
    for wl in ("mnist", "cifar10"):
        costs = paper_workload(wl)
        for bits, tag in ((None, "fp32"), ((32.0, 8.0), "int8_cloud")):
            eff = costs if bits is None else costs.with_bits(*bits)
            sim_costs = from_workload(costs, 2, bits_per_param=bits)
            for k1, k2 in PARITY_KAPPAS:
                want_t = cloud_interval_time(eff, k1, k2)
                want_e = cloud_interval_energy(eff, k1, k2)
                for tree_name, tree in trees.items():
                    res = simulate_round(build_round_dag(tree, (k1, k2)), sim_costs)
                    rel_t = abs(float(res.round_time[0]) - want_t) / want_t
                    rel_e = float(
                        np.max(np.abs(res.client_energy[0] - want_e)) / want_e
                    )
                    rel = max(rel_t, rel_e)
                    if rel > worst:
                        worst, worst_at = rel, f"{wl}/{tag}/{tree_name}/k{k1}x{k2}"
    cc = ClusterCosts(t_step=1e-3, t_edge_agg=2e-4, t_cloud_agg=2e-3)
    res = simulate_round(
        build_round_dag(trees["uniform"], (6, 10)), from_cluster(cc, 2)
    )
    want = cc.interval_time(6, 10)
    rel = abs(float(res.round_time[0]) - want) / want
    if rel > worst:
        worst, worst_at = rel, "cluster/k6x10"
    out = {"max_rel_err": worst, "worst_at": worst_at, "tol": PARITY_TOL,
           "ok": worst < PARITY_TOL}
    print(f"sim_parity,max_rel_err={worst:.3e},at={worst_at},ok={out['ok']}")
    return out


def _replay_scenario(name: str, trials: int):
    from repro.fed import scenarios
    from repro.sim import simulate_spec

    return simulate_spec(scenarios.get(name), trials=trials)


def determinism_section(trials: int) -> dict:
    """Fresh seeded build x2 must produce bit-identical distributions."""
    a = _replay_scenario("congested_backhaul", trials)
    b = _replay_scenario("congested_backhaul", trials)
    identical = bool(
        np.array_equal(a.finish, b.finish) and np.array_equal(a.energy, b.energy)
    )
    out = {"trials": trials, "bit_identical": identical,
           "p99_s": a.percentiles()["p99_s"]}
    print(f"sim_determinism,trials={trials},bit_identical={identical}")
    return out


def scenarios_section(trials: int) -> dict:
    """Percentiles + analytic tail ratio for the registered sim scenarios."""
    from repro.core.cost_model import cloud_interval_time, paper_workload
    from repro.fed import scenarios

    out = {}
    for name in ("congested_backhaul", "hetero_clients_assoc", "straggler_tail"):
        spec = scenarios.get(name)
        res = _replay_scenario(name, trials)
        k = spec.schedule.kappas
        analytic = cloud_interval_time(paper_workload(spec.cost.workload), k[0], k[1])
        s = res.summary()
        p = s["round_time"]
        row = {
            "kappas": list(k),
            "trials": trials,
            "round_time": p,
            "energy_per_client_j": s["energy_per_client_j"],
            "analytic_s": analytic,
            "tail_ratio_p99": p["p99_s"] / analytic,
            "cdf": res.cdf(17),
        }
        out[name] = row
        print(
            f"sim_scenario_{name},p50={p['p50_s']:.3f}s,p99={p['p99_s']:.3f}s,"
            f"analytic={analytic:.3f}s,tail_ratio={row['tail_ratio_p99']:.3f}"
        )
    return out


def association_section(trials: int) -> dict:
    """HFEL association on the heterogeneous scenario: before/after p99."""
    from repro.core.cost_model import paper_workload
    from repro.core.hierarchy import as_hierarchy
    from repro.fed import scenarios
    from repro.sim import from_workload, optimize_association

    spec = scenarios.get("hetero_clients_assoc")
    tree = as_hierarchy(spec.topology.build())
    costs = from_workload(paper_workload(spec.cost.workload), tree.depth)
    net = spec.network.build(tree)
    result = optimize_association(
        tree, costs, net, spec.schedule.kappas, trials=trials,
        objective="p99_time", top_k=6, max_rounds=6,
    )
    out = {
        "scenario": "hetero_clients_assoc",
        "trials": trials,
        "p99_before_s": result.value_before,
        "p99_after_s": result.value_after,
        **{k: v for k, v in result.to_dict().items() if k not in ("value_before", "value_after")},
    }
    print(
        f"sim_association,p99_before={result.value_before:.3f}s,"
        f"p99_after={result.value_after:.3f}s,"
        f"improvement={100 * result.improvement:.1f}%,"
        f"moves={len(result.moves)},evals={result.evals}"
    )
    return out


def main(smoke: bool = False, trials: int = 0, json_path: str = DEFAULT_JSON) -> dict:
    trials = trials or (40 if smoke else 200)
    assoc_trials = max(trials // 2, 16)
    sim = {
        "smoke": bool(smoke),
        "parity": parity_section(),
        "determinism": determinism_section(min(trials, 40)),
        "scenarios": scenarios_section(trials),
        "association": association_section(assoc_trials),
    }
    if json_path:
        from benchmarks.common import merge_write_json

        merge_write_json(json_path, {"bench": "round_time_sim", "sim": sim})
        print(f"wrote {json_path}")
    if smoke:
        if not sim["parity"]["ok"]:
            raise SystemExit(
                f"zero-variance parity drift: max_rel_err="
                f"{sim['parity']['max_rel_err']:.3e} (tol {PARITY_TOL})"
            )
        if not sim["determinism"]["bit_identical"]:
            raise SystemExit("replay not bit-identical across two seeded runs")
        assoc = sim["association"]
        if assoc["p99_after_s"] > assoc["p99_before_s"]:
            raise SystemExit(
                f"association made p99 worse: {assoc['p99_before_s']:.3f}s -> "
                f"{assoc['p99_after_s']:.3f}s"
            )
    return sim


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced trials + hard gates (parity, determinism, association)")
    ap.add_argument("--trials", type=int, default=0, help="replay trials (0 = default)")
    ap.add_argument("--json", default=DEFAULT_JSON, metavar="OUT.json",
                    help="merge-preserving output file ('' disables)")
    args = ap.parse_args()
    main(smoke=args.smoke, trials=args.trials, json_path=args.json)
