"""Paper Fig. 4: accuracy vs training epoch under the two guidelines.

(a) edge-IID: fixed kappa1*kappa2 = 60, kappa1 in {60, 30, 15, 6} — smaller
    kappa1 reaches accuracy in fewer local epochs; and with kappa1 fixed,
    raising kappa2 is nearly free (curves coincide).
(b) edge-NIID: same sweeps — raising kappa2 now hurts.
"""
from benchmarks.common import run_schedule


def main(csv=True):
    out = {}
    for dist in ("edge_iid", "edge_niid"):
        for k1, k2 in ((60, 1), (30, 2), (15, 4), (6, 10)):
            r = run_schedule(k1, k2, partition=dist, rounds=240 // k1)
            accs = [h.accuracy for h in r.history if h.accuracy is not None]
            steps = [h.step for h in r.history if h.accuracy is not None]
            out[(dist, k1, k2)] = (steps, accs)
            tag = f"fig4_{dist}_k1={k1}_k2={k2}"
            print(f"{tag},final_acc={accs[-1]:.3f},steps={steps[-1]}")
    # guideline 1 check: at equal local-step budget, smaller kappa1 >= larger
    for dist in ("edge_iid", "edge_niid"):
        a60 = out[(dist, 60, 1)][1][-1]
        a6 = out[(dist, 6, 10)][1][-1]
        print(f"fig4_{dist}_guideline1,small_k1_acc={a6:.3f},large_k1_acc={a60:.3f},holds={a6 >= a60 - 0.02}")
    return out


if __name__ == "__main__":
    main()
