"""Kernel micro-benches: interpret-mode correctness + jnp-reference timing.

CPU wall-times are only indicative (the kernels TARGET TPU); what this
bench pins down is (a) allclose vs oracle at bench shapes and (b) the
HBM-traffic model of each kernel vs its reference (the structural win).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core.hierarchy import parse_fanouts
from repro.kernels import ops, ref


def main(csv=True):
    rng = np.random.default_rng(0)
    ops.set_interpret(True)
    checks = {}

    # hier_aggregate: N=32 clients, 1M-param block
    x = jnp.asarray(rng.normal(size=(32, 1 << 20)), jnp.float32)
    w = jnp.asarray(rng.uniform(1, 2, size=32), jnp.float32)
    t_ref, out_ref = timed(lambda: ref.grouped_mean_ref(x, w, 8), iters=3)
    ok = checks["hier_aggregate"] = bool(np.allclose(ops.grouped_mean(x, w, 8), out_ref, atol=1e-5))
    # traffic: kernel = 2 passes (read+write) vs ref ~4 passes
    print(f"kernel_hier_aggregate,ref_us={t_ref*1e6:.0f},allclose={ok},hbm_passes=2_vs_4")

    # ragged vs uniform kernel at EQUAL total parameters (same (N, D) stack,
    # same 8 groups; ragged fan-out 8,6,6,4,3,2,2,1). Acceptance: the
    # segment-boundary encoding costs < 1.25x the uniform reshape path.
    n, d, bd = 32, 1 << 16, 8192
    spec = parse_fanouts("8,6,6,4,3,2,2,1/8")
    seg = spec.segments(1)
    xs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    t_uni, _ = timed(lambda: ops.grouped_mean(xs, w, 8, block_d=bd), iters=5)
    t_rag, out_rag = timed(lambda: ops.segment_mean(xs, w, seg, 8, block_d=bd), iters=5)
    ok = checks["hier_aggregate_ragged"] = bool(
        np.allclose(out_rag, ref.segment_mean_ref(xs, w, seg, 8, block_d=bd), atol=1e-5)
    )
    ratio = t_rag / t_uni
    print(
        f"kernel_hier_aggregate_ragged,uniform_us={t_uni*1e6:.0f},"
        f"ragged_us={t_rag*1e6:.0f},ratio={ratio:.2f},within_1.25x={ratio <= 1.25},"
        f"allclose={ok}"
    )

    # flash attention: 1k seq
    q = jnp.asarray(rng.normal(size=(4, 1024, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(4, 1024, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(4, 1024, 64)), jnp.bfloat16)
    t_ref, out_ref = timed(lambda: ref.attention_ref(q, k, v, causal=True), iters=3)
    got = ops.flash_attention(q, k, v, causal=True)
    ok = checks["flash_attention"] = bool(
        np.allclose(np.asarray(got, np.float32), np.asarray(out_ref, np.float32), atol=5e-2)
    )
    s, d = 1024, 64
    naive_hbm = s * s * 4  # score tensor per head-pair
    flash_hbm = 3 * s * d * 2 + s * d * 2
    print(f"kernel_flash_attention,ref_us={t_ref*1e6:.0f},allclose={ok},hbm_ratio={naive_hbm/flash_hbm:.1f}x")

    # rglru scan: 8k seq
    a = jnp.asarray(rng.uniform(0.9, 0.999, size=(2, 8192, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, 8192, 256)) * 0.1, jnp.float32)
    h0 = jnp.zeros((2, 256), jnp.float32)
    t_ref, (h_ref, _) = timed(lambda: ref.rglru_scan_ref(a, b, h0), iters=3)
    h_k, _ = ops.rglru_scan(a, b, h0)
    ok = checks["rglru_scan"] = bool(np.allclose(h_k, h_ref, atol=1e-4))
    print(f"kernel_rglru_scan,ref_us={t_ref*1e6:.0f},allclose={ok},hbm_passes=1_vs_logS")

    # quantize: 8M params
    x = jnp.asarray(rng.normal(size=(8 << 20,)), jnp.float32)
    t_ref, _ = timed(lambda: ref.quantize_ref(x), iters=3)
    qk, sk, shp = ops.quantize_int8(x)
    qr, sr, _ = ref.quantize_ref(x)
    ok = checks["quantize"] = bool(np.array_equal(np.asarray(qk), np.asarray(qr)))
    print(f"kernel_quantize,ref_us={t_ref*1e6:.0f},payload_match={ok},wire_ratio=3.9x_smaller")

    # fused dequantize-aggregate: int8 link payloads reduced in one HBM pass
    # (vs dequantize-to-f32 then aggregate = 1 int8 + 2 f32 passes)
    n, d, bd = 32, 1 << 16, 8192
    dq_seg = parse_fanouts("8,6,6,4,3,2,2,1/8").segments(1)
    dq_w = jnp.asarray(rng.uniform(1, 2, size=n), jnp.float32)
    deltas = jnp.asarray(rng.normal(size=(n, d)) * 0.05, jnp.float32)
    q, s = ops.quantize_stacked(deltas, qblock=256)
    ref_jit = jax.jit(functools.partial(
        ref.segment_dequant_mean_ref, num_segments=8, block_d=bd))
    t_ref, out_ref = timed(lambda: ref_jit(q, s, dq_w, dq_seg), iters=3)
    got = ops.segment_dequant_mean(q, s, dq_w, dq_seg, 8, block_d=bd)
    bitexact = checks["dequant_aggregate"] = bool(np.array_equal(np.asarray(got), np.asarray(out_ref)))
    int8_bytes = q.size + 4 * s.size
    f32_bytes = 4 * deltas.size
    print(
        f"kernel_dequant_aggregate,ref_us={t_ref*1e6:.0f},bitexact={bitexact},"
        f"payload_bytes_ratio={f32_bytes/int8_bytes:.2f}x_smaller,hbm_passes=1_vs_3"
    )

    # fused edge-interval megakernel: kappa1 SGD steps + edge mean in one
    # pass, E=4 edges x 8 clients, P=8192 (64x128), b=2. ULP tolerance vs
    # the jnp oracle (shared step body; contraction lowering differs inside
    # the Pallas interpreter — documented in kernels/ref.py)
    ne, cpe, k1, b, feat, outd = 4, 8, 4, 2, 64, 128
    n = ne * cpe
    mp = jnp.asarray(rng.normal(size=(n, feat * outd)) * 0.05, jnp.float32)
    mx = jnp.asarray(rng.normal(size=(n, k1, b, feat)), jnp.float32)
    my = jnp.asarray(rng.normal(size=(n, k1, b, outd)), jnp.float32)
    mw = jnp.asarray(rng.uniform(1, 2, size=(n,)), jnp.float32)
    t_ref, (p_ref, l_ref, _) = timed(
        lambda: ref.edge_interval_ref(mp, mx, my, mw, ne, feat=feat, lr=0.05), iters=3
    )
    p_k, l_k, _ = ops.edge_interval(mp, mx, my, mw, num_edges=ne, feat=feat, lr=0.05)
    ok = checks["edge_interval_megakernel"] = bool(
        np.allclose(np.asarray(p_k), np.asarray(p_ref), rtol=3e-6, atol=5e-7)
        and np.allclose(np.asarray(l_k), np.asarray(l_ref), rtol=3e-6, atol=5e-7)
    )
    # traffic: params+momentum cross HBM once per interval vs once per step
    print(
        f"kernel_edge_interval,ref_us={t_ref*1e6:.0f},allclose={ok},"
        f"hbm_param_passes=2_vs_{2 * k1}"
    )

    bad = sorted(k for k, v in checks.items() if not v)
    if bad:
        # a kernel drifting off its oracle must fail the build (CI smoke step)
        raise RuntimeError(f"kernel checks failed vs oracle: {bad}")
    return checks


if __name__ == "__main__":
    main()
