"""Benchmark entry point: one bench per paper table/figure + framework
benches. ``PYTHONPATH=src python -m benchmarks.run [--only name]``."""
import argparse
import time
import traceback

from benchmarks import (
    aggregation_scaling,
    fig2_topologies,
    fig4_convergence,
    kernel_bench,
    roofline_report,
    table1_cost_model,
    table2_latency_energy,
)

BENCHES = {
    "table1_cost_model": table1_cost_model.main,
    "fig4_convergence": fig4_convergence.main,
    "table2_latency_energy": table2_latency_energy.main,
    "fig2_topologies": fig2_topologies.main,
    "kernel_bench": kernel_bench.main,
    "aggregation_scaling": aggregation_scaling.main,
    "roofline_report": roofline_report.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = []
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"{name},elapsed_s={time.time()-t0:.1f}")
        except Exception as e:
            failures.append(name)
            print(f"{name},FAILED: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benches failed: {failures}")


if __name__ == "__main__":
    main()
