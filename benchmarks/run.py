"""Benchmark entry point: one bench per paper table/figure + framework
benches. ``PYTHONPATH=src python -m benchmarks.run [--only name]
[--json out.json]``.

``--json`` writes per-bench machine-readable results (status, wall time,
and whatever the bench's ``main()`` returned) — the start of the
``BENCH_*.json`` perf trajectory; CI runs the kernel bench through it as
an interpret-mode smoke gate.

Scenario mode runs one named ``repro.fed.scenarios`` registry entry
end-to-end through the public ``ExperimentSpec`` API instead of the bench
table — the CI path that exercises declarative assembly:

    PYTHONPATH=src python -m benchmarks.run --scenario trimmed_edge \\
        --set run.num_rounds=8 --json BENCH_scenario.json
    PYTHONPATH=src python -m benchmarks.run --list-scenarios
"""
import argparse
import json
import time
import traceback

from benchmarks import (
    aggregation_scaling,
    compression_tradeoff,
    fig2_topologies,
    fig4_convergence,
    kernel_bench,
    roofline_report,
    round_time_sim,
    steps_per_sec,
    table1_cost_model,
    table2_latency_energy,
)

BENCHES = {
    "table1_cost_model": table1_cost_model.main,
    "fig4_convergence": fig4_convergence.main,
    "table2_latency_energy": table2_latency_energy.main,
    "fig2_topologies": fig2_topologies.main,
    "kernel_bench": kernel_bench.main,
    "aggregation_scaling": aggregation_scaling.main,
    "compression_tradeoff": compression_tradeoff.main,
    "roofline_report": roofline_report.main,
    "round_time_sim": round_time_sim.main,
    "steps_per_sec": steps_per_sec.main,
}


def run_scenario(name: str, overrides) -> dict:
    """Build + train one registry scenario; returns a summary row."""
    from repro.fed import scenarios

    spec = scenarios.get(name, overrides=overrides)
    print(spec.describe(), flush=True)
    t0 = time.time()
    runner, state = spec.run_experiment()
    accs = [h.accuracy for h in runner.history if h.accuracy is not None]
    out = {
        "scenario": name,
        "overrides": list(overrides),
        "rounds": len(runner.history),
        "steps": int(runner.history[-1].step),
        "final_loss": float(runner.history[-1].loss),
        "final_accuracy": accs[-1] if accs else None,
        "sim_time_s": runner.history[-1].sim_time_s,
        "wire_mb": runner.history[-1].wire_mb,
        "elapsed_s": round(time.time() - t0, 3),
    }
    print(
        f"scenario={name},rounds={out['rounds']},steps={out['steps']},"
        f"loss={out['final_loss']:.4f},acc={out['final_accuracy']},"
        f"elapsed_s={out['elapsed_s']:.1f}"
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="write per-bench machine-readable results")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="run one repro.fed.scenarios registry entry instead of the benches")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    metavar="PATH=VALUE", help="dotted-path spec override (with --scenario)")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="print the scenario registry and exit")
    args = ap.parse_args()
    if args.list_scenarios:
        from repro.fed import scenarios

        for name, desc in scenarios.describe_all():
            print(f"{name:22s} {desc}")
        return
    if args.scenario:
        if args.only:
            raise SystemExit("--only does not apply with --scenario")
        result = run_scenario(args.scenario, args.overrides)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({args.scenario: {"status": "ok", **result}}, f, indent=2, default=str)
            print(f"wrote {args.json}")
        return
    if args.overrides:
        raise SystemExit("--set only applies with --scenario")
    if args.only and args.only not in BENCHES:
        # an unknown name must not silently pass (CI gates on this entry point)
        raise SystemExit(f"unknown bench {args.only!r}; choose from {sorted(BENCHES)}")
    failures = []
    results = {}
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            ret = fn()
            elapsed = time.time() - t0
            results[name] = {"status": "ok", "elapsed_s": round(elapsed, 3), "result": ret}
            print(f"{name},elapsed_s={elapsed:.1f}")
        except Exception as e:
            failures.append(name)
            results[name] = {
                "status": "failed", "elapsed_s": round(time.time() - t0, 3),
                "error": f"{type(e).__name__}: {e}",
            }
            print(f"{name},FAILED: {e}")
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            # default=str keeps numpy scalars / dataclasses serializable
            json.dump(results, f, indent=2, default=str)
        print(f"wrote {args.json}")
    if failures:
        raise SystemExit(f"benches failed: {failures}")


if __name__ == "__main__":
    main()
