"""Benchmark entry point: one bench per paper table/figure + framework
benches. ``PYTHONPATH=src python -m benchmarks.run [--only name]
[--json out.json]``.

``--json`` writes per-bench machine-readable results (status, wall time,
and whatever the bench's ``main()`` returned) — the start of the
``BENCH_*.json`` perf trajectory; CI runs the kernel bench through it as
an interpret-mode smoke gate.
"""
import argparse
import json
import time
import traceback

from benchmarks import (
    aggregation_scaling,
    compression_tradeoff,
    fig2_topologies,
    fig4_convergence,
    kernel_bench,
    roofline_report,
    steps_per_sec,
    table1_cost_model,
    table2_latency_energy,
)

BENCHES = {
    "table1_cost_model": table1_cost_model.main,
    "fig4_convergence": fig4_convergence.main,
    "table2_latency_energy": table2_latency_energy.main,
    "fig2_topologies": fig2_topologies.main,
    "kernel_bench": kernel_bench.main,
    "aggregation_scaling": aggregation_scaling.main,
    "compression_tradeoff": compression_tradeoff.main,
    "roofline_report": roofline_report.main,
    "steps_per_sec": steps_per_sec.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="write per-bench machine-readable results")
    args = ap.parse_args()
    if args.only and args.only not in BENCHES:
        # an unknown name must not silently pass (CI gates on this entry point)
        raise SystemExit(f"unknown bench {args.only!r}; choose from {sorted(BENCHES)}")
    failures = []
    results = {}
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            ret = fn()
            elapsed = time.time() - t0
            results[name] = {"status": "ok", "elapsed_s": round(elapsed, 3), "result": ret}
            print(f"{name},elapsed_s={elapsed:.1f}")
        except Exception as e:
            failures.append(name)
            results[name] = {
                "status": "failed", "elapsed_s": round(time.time() - t0, 3),
                "error": f"{type(e).__name__}: {e}",
            }
            print(f"{name},FAILED: {e}")
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            # default=str keeps numpy scalars / dataclasses serializable
            json.dump(results, f, indent=2, default=str)
        print(f"wrote {args.json}")
    if failures:
        raise SystemExit(f"benches failed: {failures}")


if __name__ == "__main__":
    main()
