"""Wall-clock training throughput: per-round driver vs superround engine
vs the client-sharded (mesh) superround.

The first entry in the repo's perf trajectory (``BENCH_throughput.json``).
This bench measures the *driver*, not the kernels: the model is a
deliberately small CNN (one 3x3 im2col conv + global average pool + fc, so
XLA lowers the vmapped per-client graph to batched matmuls) and the
per-client batch is tiny — the regime where the per-round loop's fixed
costs (a Python dispatch, a blocking host sync for step/loss, a
synchronous batch gather + upload, an un-donated FedState round-trip)
dominate each edge interval, exactly the overheads the superround engine
(``fed.engine``) amortizes over a whole cloud interval. The batch-8 sweep
point shows the compute-bound other end honestly: when the executable
dominates, both drivers converge — and it is where the sharded engine has
real per-device work to parallelize.

Protocol: all drivers share one compiled executable apiece; after a
warmup chunk (compile + cache warm), alternating timed chunks (order
rotated every rep to cancel clock drift) of whole cloud intervals, median
over reps.

    PYTHONPATH=src python -m benchmarks.steps_per_sec            # full sweep
    PYTHONPATH=src python -m benchmarks.steps_per_sec --json     # + BENCH_throughput.json
    PYTHONPATH=src python -m benchmarks.steps_per_sec --smoke    # CI gate:
        # headline shape only, fails if the engine is slower than per-round
    PYTHONPATH=src python -m benchmarks.steps_per_sec --devices 4 --json
        # + client-sharded rows over 4 (possibly simulated) devices
    PYTHONPATH=src python -m benchmarks.steps_per_sec --devices 4 --smoke
        # multi-device CI gate: sharded engine must not collapse vs 1 device
    PYTHONPATH=src python -m benchmarks.steps_per_sec --population --json
        # population-scale cohort engine only: steady-state client_steps_per_s
        # on the n1m_cohort4096 scenario, merged into BENCH_throughput.json
    PYTHONPATH=src python -m benchmarks.steps_per_sec --population --devices 4 --json
        # + "population_sharded": the same scenario through the sharded
        # cohort engine over a 4-way client mesh, vs the 1-device cohort
        # engine (with --smoke: fails below the 0.7x collapse floor)

``--devices K`` must be seen before JAX initializes: this module reads it
from ``sys.argv`` at import time and sets
``--xla_force_host_platform_device_count`` so a CPU host simulates the
mesh (real multi-device backends need no flag).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _early_devices() -> int:
    argv = sys.argv[1:]
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 0


_EARLY_DEVICES = _early_devices()
if _EARLY_DEVICES > 1 and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_EARLY_DEVICES}"
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import FedTopology, HierFAVGConfig  # noqa: E402
from repro.data import FederatedBatcher, clustered_gaussians, make_partition  # noqa: E402
from repro.dist.sharding import client_mesh  # noqa: E402
from repro.fed import FederatedRunner, RunnerConfig  # noqa: E402
from repro.models import cnn  # noqa: E402
from repro.optim import sgd  # noqa: E402

DIM = (8, 8, 1)
HEADLINE = "N64_k4x4"
# name -> (num_clients, num_edges, kappas, batch)
SHAPES = {
    "N16_k2x2": (16, 4, (2, 2), 1),
    "N64_k4x4": (64, 8, (4, 4), 1),
    "N64_k8x2": (64, 8, (8, 2), 1),
    "N64_k4x4_b2": (64, 8, (4, 4), 2),  # middle of the b in {1,2,8} sweep
    "N64_k4x4_b8": (64, 8, (4, 4), 8),  # compute-bound contrast point
}
# the --devices sweep: N64 shapes, a batch sweep b in {1,2,8} from
# dispatch-bound to compute-bound (where per-device parallelism has actual
# work to split)
SHARDED_SHAPES = ("N64_k4x4", "N64_k4x4_b2", "N64_k4x4_b8")
SHARDED_SMOKE_SHAPE = "N64_k4x4_b8"
# the multi-device CI gate is a catastrophic-regression floor, not a
# scaling promise: simulated CPU devices split one host's cores, so the
# parallel win tracks the core count, not the device count
SHARDED_SMOKE_FLOOR = 0.5
# batch-1 floor: dispatch-bound sharding historically regressed to 0.82x of
# one device; the RNG-hoisted scan must keep it from sliding further (floor
# is lenient because alternating-chunk medians still move ~30% rep-to-rep
# on shared CI hosts)
SHARDED_B1_SHAPE = "N64_k4x4"
SHARDED_B1_FLOOR = 0.7

# the megakernel contrast point: per-client state past cache (P=307210 MLP,
# 64 -> 4096 -> 10), batch 1 — the regime the client-blocked edge-interval
# lowering targets (params block-resident across all kappa1 steps instead of
# the step-major full-stack sweep)
MEGAKERNEL_SHAPE = "mlp307k_N32_k8x2"
MEGAKERNEL_GEOM = (32, 4, (8, 2), 1)  # clients, edges, kappas, batch
MLP_HIDDEN = 4096


def _patches(x, k=3):
    """im2col: (B,H,W,C) -> (B,H-k+1,W-k+1,k*k*C) via static slices, so the
    conv is a batched matmul under vmap (fast CPU lowering)."""
    slices = [
        x[:, i : x.shape[1] - k + 1 + i, j : x.shape[2] - k + 1 + j, :]
        for i in range(k)
        for j in range(k)
    ]
    return jnp.concatenate(slices, axis=-1)


def bench_cnn_init(rng):
    k = jax.random.split(rng, 2)
    return {
        "c1w": jax.random.normal(k[0], (9, 16)) * 0.25,
        "c1b": jnp.zeros((16,)),
        "fw": jax.random.normal(k[1], (16, 10)) * 0.3,
        "fb": jnp.zeros((10,)),
    }


def bench_cnn_apply(p, x):
    x = jax.nn.relu(_patches(x) @ p["c1w"] + p["c1b"])  # (B,6,6,16)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return x @ p["fw"] + p["fb"]


def bench_mlp_init(rng):
    k = jax.random.split(rng, 2)
    d = DIM[0] * DIM[1] * DIM[2]
    return {
        "w1": jax.random.normal(k[0], (d, MLP_HIDDEN)) / np.sqrt(d),
        "b1": jnp.zeros((MLP_HIDDEN,)),
        "w2": jax.random.normal(k[1], (MLP_HIDDEN, 10)) * 0.02,
        "b2": jnp.zeros((10,)),
    }


def bench_mlp_apply(p, x):
    x = x.reshape((x.shape[0], -1))
    x = jax.nn.relu(x @ p["w1"] + p["b1"])
    return x @ p["w2"] + p["b2"]


def _make_runner(engine, num_clients, num_edges, kappas, batch, seed=0, mesh=None, model="cnn"):
    rng = np.random.default_rng(seed)
    data = clustered_gaussians(
        rng, num_samples=num_clients * 40, num_classes=10, dim=DIM, class_sep=2.0
    )
    parts = make_partition("edge_iid", data.y, num_edges, num_clients // num_edges, rng)
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=batch, seed=seed
    )
    apply_fn = bench_mlp_apply if model == "mlp" else bench_cnn_apply
    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(0.1),
        topology=FedTopology(num_edges=num_edges, clients_per_edge=num_clients // num_edges),
        hier_config=HierFAVGConfig(kappa1=kappas[0], kappa2=kappas[1]),
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=0, engine=engine),
        mesh=mesh,
    )
    init_fn = bench_mlp_init if model == "mlp" else bench_cnn_init
    state = runner.init(jax.random.PRNGKey(seed), init_fn(jax.random.PRNGKey(seed + 1)))
    return runner, state


def _timed_chunk(runner, state, start_round, rounds):
    runner.cfg.num_rounds = start_round + rounds
    t0 = time.perf_counter()
    state = runner.run(state, start_round=start_round)
    jax.block_until_ready(state.params)
    return time.perf_counter() - t0, state


def run_shape(name, *, reps=5, intervals=20, warmup_intervals=2, devices=0):
    """Time whole-cloud-interval chunks per driver. ``devices > 1`` adds a
    "sharded" driver: the superround engine over a ``devices``-way client
    mesh (same executable protocol, same alternation)."""
    num_clients, num_edges, kappas, batch = SHAPES[name]
    k1, k2 = kappas
    chunk = intervals * k2

    modes = ["per_round", "superround"] + (["sharded"] if devices > 1 else [])
    drivers = {}
    for mode in modes:
        mesh = client_mesh(devices) if mode == "sharded" else None
        engine = "superround" if mode == "sharded" else mode
        runner, state = _make_runner(engine, num_clients, num_edges, kappas, batch, mesh=mesh)
        _, state = _timed_chunk(runner, state, 0, warmup_intervals * k2)  # compile + warm
        drivers[mode] = {"runner": runner, "state": state, "done": warmup_intervals * k2, "times": []}

    for rep in range(reps):
        shift = rep % len(modes)
        order = modes[shift:] + modes[:shift]
        for mode in order:
            d = drivers[mode]
            dt, d["state"] = _timed_chunk(d["runner"], d["state"], d["done"], chunk)
            d["done"] += chunk
            d["times"].append(dt)

    out = {"num_clients": num_clients, "kappas": list(kappas), "batch": batch}
    for mode in modes:
        med = float(np.median(drivers[mode]["times"]))
        out[mode] = {
            "ms_per_round": round(med / chunk * 1000, 4),
            "local_steps_per_s": round(chunk * k1 / med, 2),
            "client_steps_per_s": round(chunk * k1 * num_clients / med, 1),
        }
    out["speedup"] = round(
        out["superround"]["local_steps_per_s"] / out["per_round"]["local_steps_per_s"], 3
    )
    if "sharded" in drivers:
        out["devices"] = devices
        out["sharded_speedup_vs_superround"] = round(
            out["sharded"]["local_steps_per_s"] / out["superround"]["local_steps_per_s"], 3
        )
    return out


def run_megakernel_shape(*, reps=5, intervals=8, warmup_intervals=1):
    """Time the fused edge-interval megakernel engine against the scan-fused
    superround at the megakernel's design shape (large per-client state,
    batch 1). Same executable/alternation protocol as ``run_shape``."""
    num_clients, num_edges, kappas, batch = MEGAKERNEL_GEOM
    k1, k2 = kappas
    chunk = intervals * k2

    modes = ["superround", "megakernel"]
    drivers = {}
    for mode in modes:
        runner, state = _make_runner(mode, num_clients, num_edges, kappas, batch, model="mlp")
        _, state = _timed_chunk(runner, state, 0, warmup_intervals * k2)
        if mode == "megakernel" and not runner._engine.uses_megakernel:
            raise SystemExit(
                f"megakernel engine fell back at the bench shape: "
                f"{runner._engine.megakernel_reason}"
            )
        drivers[mode] = {"runner": runner, "state": state, "done": warmup_intervals * k2, "times": []}

    for rep in range(reps):
        shift = rep % len(modes)
        order = modes[shift:] + modes[:shift]
        for mode in order:
            d = drivers[mode]
            dt, d["state"] = _timed_chunk(d["runner"], d["state"], d["done"], chunk)
            d["done"] += chunk
            d["times"].append(dt)

    out = {"num_clients": num_clients, "kappas": list(kappas), "batch": batch,
           "model": f"mlp_h{MLP_HIDDEN}", "params_per_client": 307210}
    for mode in modes:
        med = float(np.median(drivers[mode]["times"]))
        out[mode] = {
            "ms_per_round": round(med / chunk * 1000, 4),
            "local_steps_per_s": round(chunk * k1 / med, 2),
            "client_steps_per_s": round(chunk * k1 * num_clients / med, 1),
        }
    out["megakernel_speedup_vs_superround"] = round(
        out["megakernel"]["local_steps_per_s"] / out["superround"]["local_steps_per_s"], 3
    )
    return out


POPULATION_SCENARIO = "n1m_cohort4096"
# the sharded-cohort CI gate is (like the full-population one) a
# catastrophic-regression floor: simulated devices split one host's cores
POPULATION_SHARDED_FLOOR = 0.7


def run_population(name=POPULATION_SCENARIO, *, reps=3, intervals=4,
                   warmup_intervals=1, devices=0):
    """Steady-state throughput of the sampled-participation cohort engine on
    a virtual-client population scenario. Only the cohort is device-resident,
    so this times the full streaming loop: host-side cohort sampling + lazy
    per-client batch synthesis (overlapped in the prefetch worker), sticky-row
    store swap, and the donated cohort superround. One warmup interval pays
    compilation; timed chunks of whole cloud intervals, median over reps.

    With ``devices > 1`` a second driver runs the same scenario through the
    sharded cohort engine (``topology.mesh_axes=clients:K``) with the same
    alternating-chunk protocol, and a ``(single_row, sharded_section)`` pair
    is returned; otherwise ``(single_row, None)``.
    """
    from repro.fed import scenarios
    from repro.fed.engine import CohortEngine

    def make_driver(overrides):
        spec = scenarios.get(name, overrides)
        runner = spec.build()
        state = runner.init(
            jax.random.PRNGKey(spec.run.seed),
            spec.init_params(jax.random.PRNGKey(spec.run.seed + 1)),
        )
        return {"spec": spec, "runner": runner, "engine": CohortEngine(runner),
                "state": state, "intervals": 0, "times": []}

    modes = ["single"] + (["sharded"] if devices > 1 else [])
    drivers = {"single": make_driver([])}
    if devices > 1:
        drivers["sharded"] = make_driver([f"topology.mesh_axes=clients:{devices}"])
    k1 = drivers["single"]["runner"].hier_config.kappa1
    k2 = drivers["single"]["runner"].hier_config.kappa2_effective
    cohort = int(drivers["single"]["runner"].participation.cohort_size)

    def chunk(d, n):
        t0 = time.perf_counter()
        d["state"], _ = d["engine"].run_intervals(
            d["state"], start_round=d["intervals"] * k2, num_intervals=n
        )
        jax.block_until_ready(d["state"].params)
        d["intervals"] += n
        return time.perf_counter() - t0

    for mode in modes:
        chunk(drivers[mode], warmup_intervals)  # compile + first prefetch fill
    for rep in range(reps):
        shift = rep % len(modes)
        for mode in modes[shift:] + modes[:shift]:
            d = drivers[mode]
            d["times"].append(chunk(d, intervals))

    steps = intervals * k2 * k1  # local steps per timed chunk

    def row(d):
        med = float(np.median(d["times"]))
        store = d["runner"].client_store
        return {
            "scenario": name,
            "num_clients": int(len(d["runner"].batcher.data_sizes)),
            "cohort_size": cohort,
            "sampler": d["runner"].participation.sampler,
            "kappas": [k1, k2],
            "batch": d["spec"].data.batch_size,
            "ms_per_interval": round(med / intervals * 1000, 2),
            "local_steps_per_s": round(steps / med, 2),
            "client_steps_per_s": round(steps * cohort / med, 1),
            "client_store_mib": round((store.nbytes if store is not None else 0) / 2**20, 3),
        }

    single = row(drivers["single"])
    if devices <= 1:
        return single, None
    sh = row(drivers["sharded"])
    sharded = {
        "scenario": name,
        "devices": devices,
        "batch": single["batch"],
        "cohort_size": cohort,
        "sampler": single["sampler"],
        "single": {k: single[k] for k in
                   ("ms_per_interval", "local_steps_per_s", "client_steps_per_s")},
        "sharded": {k: sh[k] for k in
                    ("ms_per_interval", "local_steps_per_s", "client_steps_per_s")},
        "scaling_vs_1dev": round(
            sh["client_steps_per_s"] / single["client_steps_per_s"], 3
        ),
    }
    return single, sharded


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI gate: headline shape only (plus the sharded "
                         "gate shape with --devices); exit nonzero if the "
                         "superround engine is slower than the per-round driver "
                         "or the sharded engine collapses vs one device")
    ap.add_argument("--json", nargs="?", const="BENCH_throughput.json", default=None,
                    metavar="OUT.json", help="write machine-readable results "
                    "(default path: BENCH_throughput.json)")
    ap.add_argument("--population", action="store_true",
                    help="run ONLY the population-scale cohort bench "
                         f"({POPULATION_SCENARIO}): steady-state streaming "
                         "participation over a virtual-client population; with "
                         "--json the result merges into the existing file "
                         "without clobbering the shape-sweep keys")
    ap.add_argument("--devices", type=int, default=0, metavar="K",
                    help="also time the client-sharded superround over a K-way "
                         "client mesh (read pre-import: simulates K CPU devices "
                         "via --xla_force_host_platform_device_count)")
    # argv=None means a programmatic call (benchmarks.run): parse nothing
    # rather than falling back to sys.argv — the harness's own --json flag
    # must not be absorbed here and clobber its output file
    args = ap.parse_args([] if argv is None else argv)

    if args.devices > 1 and len(jax.devices()) < args.devices:
        raise SystemExit(
            f"--devices {args.devices} needs {args.devices} visible devices but "
            f"only {len(jax.devices())} exist; run this module directly "
            f"(python -m benchmarks.steps_per_sec --devices {args.devices}) so "
            f"the pre-import hook can set XLA_FLAGS, or export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    if args.population:
        names = []  # the population job times the cohort engine only
        reps, intervals, warmup = 3, 8, 1
    elif args.smoke:
        names = [] if args.devices > 1 else [HEADLINE]  # the multi-device job gates sharded only
        reps, intervals, warmup = 3, 8, 1
    else:
        names = list(SHAPES)
        reps, intervals, warmup = 5, 20, 2
    shapes = {}
    for name in names:
        shapes[name] = run_shape(name, reps=reps, intervals=intervals, warmup_intervals=warmup)
        s = shapes[name]
        print(
            f"steps_per_sec_{name},per_round={s['per_round']['local_steps_per_s']},"
            f"superround={s['superround']['local_steps_per_s']},speedup={s['speedup']}"
        )

    megakernel = None
    if names and not args.smoke:  # full sweep only: the megakernel design shape
        megakernel = {MEGAKERNEL_SHAPE: run_megakernel_shape(reps=reps)}
        row = megakernel[MEGAKERNEL_SHAPE]
        print(
            f"steps_per_sec_megakernel_{MEGAKERNEL_SHAPE},"
            f"superround={row['superround']['local_steps_per_s']},"
            f"megakernel={row['megakernel']['local_steps_per_s']},"
            f"speedup={row['megakernel_speedup_vs_superround']}"
        )

    sharded = None
    if args.devices > 1 and not args.population:
        # the smoke gate times both floors: the b8 scaling shape and the
        # dispatch-bound b1 shape (the historical 0.82x regression)
        snames = (SHARDED_SMOKE_SHAPE, SHARDED_B1_SHAPE) if args.smoke else SHARDED_SHAPES
        sharded = {"devices": args.devices, "shapes": {}}
        for name in snames:
            row = run_shape(name, reps=reps, intervals=intervals,
                            warmup_intervals=warmup, devices=args.devices)
            sharded["shapes"][name] = row
            print(
                f"steps_per_sec_sharded_{name},devices={args.devices},"
                f"superround={row['superround']['client_steps_per_s']},"
                f"sharded={row['sharded']['client_steps_per_s']},"
                f"scaling_vs_1dev={row['sharded_speedup_vs_superround']}"
            )
        gate_name = SHARDED_SMOKE_SHAPE if SHARDED_SMOKE_SHAPE in sharded["shapes"] else snames[0]
        row = sharded["shapes"][gate_name]
        sharded["headline"] = {
            "shape": gate_name,
            "devices": args.devices,
            "client_steps_per_s_1dev": row["superround"]["client_steps_per_s"],
            "client_steps_per_s_sharded": row["sharded"]["client_steps_per_s"],
            "scaling_vs_1dev": row["sharded_speedup_vs_superround"],
        }

    population = population_sharded = None
    if args.population:
        population, population_sharded = run_population(
            reps=reps, intervals=4, warmup_intervals=warmup, devices=args.devices
        )
        print(
            f"steps_per_sec_population_{population['scenario']},"
            f"num_clients={population['num_clients']},"
            f"cohort={population['cohort_size']}/{population['sampler']},"
            f"client_steps_per_s={population['client_steps_per_s']},"
            f"ms_per_interval={population['ms_per_interval']}"
        )
        if population_sharded is not None:
            print(
                f"steps_per_sec_population_sharded_{population_sharded['scenario']},"
                f"devices={population_sharded['devices']},"
                f"single={population_sharded['single']['client_steps_per_s']},"
                f"sharded={population_sharded['sharded']['client_steps_per_s']},"
                f"scaling_vs_1dev={population_sharded['scaling_vs_1dev']}"
            )

    results = {
        "bench": "steps_per_sec",
        "shapes": shapes,
        "env": {"backend": jax.default_backend(), "cpu_count": os.cpu_count(),
                "devices": len(jax.devices()), "jax": jax.__version__,
                "xla_flags": os.environ.get("XLA_FLAGS", ""),
                "smoke": bool(args.smoke)},
    }
    head = shapes.get(HEADLINE)
    if head is not None:
        results["headline"] = {
            "shape": HEADLINE,
            "speedup": head["speedup"],
            "per_round_local_steps_per_s": head["per_round"]["local_steps_per_s"],
            "superround_local_steps_per_s": head["superround"]["local_steps_per_s"],
        }
    if megakernel is not None:
        results["megakernel"] = megakernel
    if sharded is not None:
        results["sharded"] = sharded
    if population is not None:
        results["population"] = population
    if population_sharded is not None:
        results["population_sharded"] = population_sharded
    if args.json:
        # partial runs (--population, --devices-only smoke) merge into the
        # existing file rather than clobbering the other benches' keys
        from benchmarks.common import merge_write_json

        merged = merge_write_json(args.json, results, skip_empty=("shapes",))
        if not isinstance(merged.get("shapes"), dict):
            merge_write_json(args.json, {"shapes": shapes})
        print(f"wrote {args.json}")
    if head is not None and head["speedup"] < 1.5:
        print(
            f"steps_per_sec_note,headline speedup {head['speedup']} < 1.5 target "
            "(dispatch-bound regime narrows on loaded/low-core CPU hosts)"
        )
    if args.smoke and head is not None and head["speedup"] < 1.0:
        raise SystemExit(
            f"superround engine slower than per-round driver at the smoke shape "
            f"(speedup {head['speedup']} < 1.0)"
        )
    # sharded gate failures must be diagnosable from the log alone: simulated
    # devices split one host's cores, so a collapse on a 1-core runner is an
    # environment fact, not a code regression
    env_note = (
        f"[cpu_count={os.cpu_count()}, "
        f"xla_flags={os.environ.get('XLA_FLAGS', '') or '<unset>'!s}]"
    )
    if args.smoke and sharded is not None:
        # gate on the headline entry so the gate and the recorded headline
        # can never disagree about which shape they describe
        gate = sharded["headline"]["scaling_vs_1dev"]
        if gate < SHARDED_SMOKE_FLOOR:
            raise SystemExit(
                f"client-sharded superround collapsed at the gate shape "
                f"({sharded['headline']['shape']}: {gate} < {SHARDED_SMOKE_FLOOR} "
                f"of the single-device engine) {env_note}"
            )
        b1_row = sharded["shapes"].get(SHARDED_B1_SHAPE)
        if b1_row is not None:
            b1 = b1_row["sharded_speedup_vs_superround"]
            if b1 < SHARDED_B1_FLOOR:
                raise SystemExit(
                    f"batch-1 sharded throughput slid below the floor "
                    f"({SHARDED_B1_SHAPE}: {b1} < {SHARDED_B1_FLOOR} of the "
                    f"single-device engine) {env_note}"
                )
    if args.smoke and population_sharded is not None:
        gate = population_sharded["scaling_vs_1dev"]
        if gate < POPULATION_SHARDED_FLOOR:
            raise SystemExit(
                f"sharded cohort engine collapsed on {population_sharded['scenario']} "
                f"({gate} < {POPULATION_SHARDED_FLOOR} of the single-device cohort "
                f"engine over {population_sharded['devices']} devices) {env_note}"
            )
    return results


if __name__ == "__main__":
    main(sys.argv[1:])
