"""Wall-clock training throughput: per-round driver vs superround engine.

The first entry in the repo's perf trajectory (``BENCH_throughput.json``).
This bench measures the *driver*, not the kernels: the model is a
deliberately small CNN (one 3x3 im2col conv + global average pool + fc, so
XLA lowers the vmapped per-client graph to batched matmuls) and the
per-client batch is tiny — the regime where the per-round loop's fixed
costs (a Python dispatch, a blocking host sync for step/loss, a
synchronous batch gather + upload, an un-donated FedState round-trip)
dominate each edge interval, exactly the overheads the superround engine
(``fed.engine``) amortizes over a whole cloud interval. The batch-8 sweep
point shows the compute-bound other end honestly: when the executable
dominates, both drivers converge.

Protocol: both drivers share one compiled executable apiece; after a
warmup chunk (compile + cache warm), alternating timed chunks (order
flipped every rep to cancel clock drift) of whole cloud intervals, median
over reps.

    PYTHONPATH=src python -m benchmarks.steps_per_sec            # full sweep
    PYTHONPATH=src python -m benchmarks.steps_per_sec --json     # + BENCH_throughput.json
    PYTHONPATH=src python -m benchmarks.steps_per_sec --smoke    # CI gate:
        # headline shape only, fails if the engine is slower than per-round
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedTopology, HierFAVGConfig
from repro.data import FederatedBatcher, clustered_gaussians, make_partition
from repro.fed import FederatedRunner, RunnerConfig
from repro.models import cnn
from repro.optim import sgd

DIM = (8, 8, 1)
HEADLINE = "N64_k4x4"
# name -> (num_clients, num_edges, kappas, batch)
SHAPES = {
    "N16_k2x2": (16, 4, (2, 2), 1),
    "N64_k4x4": (64, 8, (4, 4), 1),
    "N64_k8x2": (64, 8, (8, 2), 1),
    "N64_k4x4_b8": (64, 8, (4, 4), 8),  # compute-bound contrast point
}


def _patches(x, k=3):
    """im2col: (B,H,W,C) -> (B,H-k+1,W-k+1,k*k*C) via static slices, so the
    conv is a batched matmul under vmap (fast CPU lowering)."""
    slices = [
        x[:, i : x.shape[1] - k + 1 + i, j : x.shape[2] - k + 1 + j, :]
        for i in range(k)
        for j in range(k)
    ]
    return jnp.concatenate(slices, axis=-1)


def bench_cnn_init(rng):
    k = jax.random.split(rng, 2)
    return {
        "c1w": jax.random.normal(k[0], (9, 16)) * 0.25,
        "c1b": jnp.zeros((16,)),
        "fw": jax.random.normal(k[1], (16, 10)) * 0.3,
        "fb": jnp.zeros((10,)),
    }


def bench_cnn_apply(p, x):
    x = jax.nn.relu(_patches(x) @ p["c1w"] + p["c1b"])  # (B,6,6,16)
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return x @ p["fw"] + p["fb"]


def _make_runner(engine, num_clients, num_edges, kappas, batch, seed=0):
    rng = np.random.default_rng(seed)
    data = clustered_gaussians(
        rng, num_samples=num_clients * 40, num_classes=10, dim=DIM, class_sep=2.0
    )
    parts = make_partition("edge_iid", data.y, num_edges, num_clients // num_edges, rng)
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=batch, seed=seed
    )
    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(bench_cnn_apply),
        optimizer=sgd(0.1),
        topology=FedTopology(num_edges=num_edges, clients_per_edge=num_clients // num_edges),
        hier_config=HierFAVGConfig(kappa1=kappas[0], kappa2=kappas[1]),
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=0, engine=engine),
    )
    state = runner.init(jax.random.PRNGKey(seed), bench_cnn_init(jax.random.PRNGKey(seed + 1)))
    return runner, state


def _timed_chunk(runner, state, start_round, rounds):
    runner.cfg.num_rounds = start_round + rounds
    t0 = time.perf_counter()
    state = runner.run(state, start_round=start_round)
    jax.block_until_ready(state.params)
    return time.perf_counter() - t0, state


def run_shape(name, *, reps=5, intervals=20, warmup_intervals=2):
    num_clients, num_edges, kappas, batch = SHAPES[name]
    k1, k2 = kappas
    chunk = intervals * k2

    drivers = {}
    for mode in ("per_round", "superround"):
        runner, state = _make_runner(mode, num_clients, num_edges, kappas, batch)
        _, state = _timed_chunk(runner, state, 0, warmup_intervals * k2)  # compile + warm
        drivers[mode] = {"runner": runner, "state": state, "done": warmup_intervals * k2, "times": []}

    for rep in range(reps):
        order = ("per_round", "superround") if rep % 2 == 0 else ("superround", "per_round")
        for mode in order:
            d = drivers[mode]
            dt, d["state"] = _timed_chunk(d["runner"], d["state"], d["done"], chunk)
            d["done"] += chunk
            d["times"].append(dt)

    out = {"num_clients": num_clients, "kappas": list(kappas), "batch": batch}
    for mode in ("per_round", "superround"):
        med = float(np.median(drivers[mode]["times"]))
        out[mode] = {
            "ms_per_round": round(med / chunk * 1000, 4),
            "local_steps_per_s": round(chunk * k1 / med, 2),
            "client_steps_per_s": round(chunk * k1 * num_clients / med, 1),
        }
    out["speedup"] = round(
        out["superround"]["local_steps_per_s"] / out["per_round"]["local_steps_per_s"], 3
    )
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="headline shape only, quick; exit nonzero if the "
                         "superround engine is slower than the per-round driver")
    ap.add_argument("--json", nargs="?", const="BENCH_throughput.json", default=None,
                    metavar="OUT.json", help="write machine-readable results "
                    "(default path: BENCH_throughput.json)")
    # argv=None means a programmatic call (benchmarks.run): parse nothing
    # rather than falling back to sys.argv — the harness's own --json flag
    # must not be absorbed here and clobber its output file
    args = ap.parse_args([] if argv is None else argv)

    names = [HEADLINE] if args.smoke else list(SHAPES)
    reps, intervals, warmup = (3, 8, 1) if args.smoke else (5, 20, 2)
    shapes = {}
    for name in names:
        shapes[name] = run_shape(name, reps=reps, intervals=intervals, warmup_intervals=warmup)
        s = shapes[name]
        print(
            f"steps_per_sec_{name},per_round={s['per_round']['local_steps_per_s']},"
            f"superround={s['superround']['local_steps_per_s']},speedup={s['speedup']}"
        )

    head = shapes[HEADLINE]
    results = {
        "bench": "steps_per_sec",
        "headline": {
            "shape": HEADLINE,
            "speedup": head["speedup"],
            "per_round_local_steps_per_s": head["per_round"]["local_steps_per_s"],
            "superround_local_steps_per_s": head["superround"]["local_steps_per_s"],
        },
        "shapes": shapes,
        "env": {"backend": jax.default_backend(), "cpu_count": os.cpu_count(),
                "jax": jax.__version__, "smoke": bool(args.smoke)},
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")
    if head["speedup"] < 1.5:
        print(
            f"steps_per_sec_note,headline speedup {head['speedup']} < 1.5 target "
            "(dispatch-bound regime narrows on loaded/low-core CPU hosts)"
        )
    if args.smoke and head["speedup"] < 1.0:
        raise SystemExit(
            f"superround engine slower than per-round driver at the smoke shape "
            f"(speedup {head['speedup']} < 1.0)"
        )
    return results


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
