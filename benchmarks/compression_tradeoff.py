"""Compression trade-off: κ-sweep × codec-sweep time-to-accuracy.

The paper trades aggregation *frequency* (κ₂) against convergence; the
transport layer adds the orthogonal axis of per-hop payload *size*. This
bench sweeps both on the MNIST-cost workload: each (κ₁, κ₂) schedule runs
under an fp32 wire, an int8 cloud hop, and int8 with error feedback at
both hops, reporting steps/T_α/E_α to the target accuracy plus the
cumulative uplink MB per client — the compounded saving of
arXiv:2103.14272 on top of HierFAVG's κ₂ lever.

Usage: ``PYTHONPATH=src python benchmarks/compression_tradeoff.py
[--alpha 0.85] [--codecs identity/identity,identity/int8]``
"""
import argparse

from benchmarks.common import first_reach, run_schedule

# (label, per-level codec string, bottom-up)
DEFAULT_CODECS = (
    ("fp32", "identity/identity"),
    ("int8_cloud", "identity/int8"),
    ("int8_ef_both", "int8_ef/int8_ef"),
)
KAPPAS = ((30, 2), (15, 4), (6, 10))


def main(csv=True, alpha=0.85, codecs=DEFAULT_CODECS, kappas=KAPPAS):
    rows = []
    print("# compression_tradeoff (mnist costs, edge_iid, alpha=%.2f)" % alpha)
    for k1, k2 in kappas:
        base = None
        for label, spec in codecs:
            r = run_schedule(
                k1, k2, partition="edge_iid", workload="mnist",
                rounds=360 // k1, transport=spec,
            )
            hit = first_reach(r, alpha)
            if hit is None:
                print(f"tradeoff_k1={k1}_k2={k2}_{label},NOT_REACHED")
                continue
            steps, T, E = hit
            wire = next(h.wire_mb for h in r.history if h.step >= steps)
            if label == codecs[0][0]:
                base = (T, E, wire)
            speedup = base[0] / T if base else float("nan")
            wire_ratio = wire / base[2] if base else float("nan")
            rows.append(
                {"k1": k1, "k2": k2, "codec": label, "steps": steps,
                 "T_s": T, "E_j": E, "wire_mb": wire,
                 "time_speedup_vs_fp32": speedup,
                 "wire_ratio_vs_fp32": wire_ratio}
            )
            print(
                f"tradeoff_k1={k1}_k2={k2}_{label},steps={steps},T={T:.1f}s,"
                f"E={E:.2f}J,wire={wire:.2f}MB,speedup={speedup:.2f}x,"
                f"bytes_ratio={wire_ratio:.2f}"
            )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=float, default=0.85)
    ap.add_argument(
        "--codecs", default=None,
        help="comma-separated per-level codec strings, e.g. 'identity/int8,int8/int8'",
    )
    args = ap.parse_args()
    codecs = DEFAULT_CODECS
    if args.codecs:
        codecs = tuple((c, c) for c in args.codecs.split(","))
    main(alpha=args.alpha, codecs=codecs)
