"""Wall-clock-vs-accuracy curves: synchronous barrier vs deadline vs
FedBuff-buffered cloud rounds under a straggler tail (``docs/robustness.md``).

Round-count convergence curves hide exactly what the semi-synchronous
engine buys: a deadline round is *cheaper in seconds* because the cloud
stops waiting for the slowest edge. This bench prices every variant on the
same event clock — per-edge cadences derived from the same
``StragglerModel`` slowness tail — and reports accuracy against simulated
wall-clock seconds:

* ``sync``      the full barrier (quorum=1.0) through the deadline engine,
                which is bit-exact with the synchronous superround engine
                (the parity contract) but carries the event clock, so the
                baseline's seconds are honest
* ``deadline``  60% quorum + staleness decay, with mid-round edge dropout
                injected (the chaos gate: graceful degradation, not a crash)
* ``buffered``  FedBuff-style: the first K=3 edge arrivals fold per round

Gates (``--smoke``, the CI chaos gate):

* every variant completes all rounds under fault injection,
* the deadline engine reaches the shared accuracy target in strictly less
  simulated wall-clock time than the synchronous barrier,
* final deadline/buffered accuracy sits within ``ACC_FLOOR`` of the
  synchronous baseline (skip-and-reweight degrades gracefully).

Results merge into ``BENCH_throughput.json`` under ``"semisync"``.

    PYTHONPATH=src python -m benchmarks.wallclock_curves --smoke
    PYTHONPATH=src python -m benchmarks.wallclock_curves --json
"""
from __future__ import annotations

import argparse

ACC_FLOOR = 0.10  # max accuracy giveback vs the synchronous baseline
TARGET_FRACTION = 0.90  # shared target = this fraction of the weaker final acc


def _base_overrides(rounds: int) -> list:
    # the straggler_tail problem on a deadline-friendly cadence: kappas=(4,5)
    # so eval can land at every cloud boundary (5 rounds) for curve resolution
    return [
        "schedule.kappas=4,5",
        "data.class_sep=2.0",
        f"run.num_rounds={rounds}",
        "run.eval_every=5",
        "failures.straggler_sigma=0.4",
        "failures.straggler_mean_s=1.0",
        "failures.seed=5",
    ]


VARIANTS = {
    "sync": ["deadline.enabled=true", "deadline.quorum=1.0"],
    "deadline": [
        "deadline.enabled=true", "deadline.quorum=0.6",
        "deadline.staleness=poly:0.5", "deadline.max_staleness=3",
        "deadline.edge_drop_rate=0.1", "deadline.retry_limit=1",
        "deadline.seed=5",
    ],
    "buffered": [
        "deadline.enabled=true", "deadline.buffer_size=3",
        "deadline.staleness=poly:0.5", "deadline.max_staleness=3",
        "deadline.seed=5",
    ],
}


def _run_variant(name: str, rounds: int) -> dict:
    from repro.fed.api import ExperimentSpec

    spec = ExperimentSpec.parse(_base_overrides(rounds) + VARIANTS[name])
    runner, _ = spec.run_experiment()
    curve = [
        {"round": h.round, "wall_s": h.wall_clock_s, "accuracy": h.accuracy}
        for h in runner.history
        if h.accuracy is not None
    ]
    return {
        "overrides": VARIANTS[name],
        "rounds": len(runner.history),
        "final_accuracy": runner.history[-1].accuracy,
        "final_wall_s": runner.history[-1].wall_clock_s,
        "curve": curve,
    }


def _time_to(curve: list, alpha: float):
    for p in curve:
        if p["accuracy"] is not None and p["accuracy"] >= alpha:
            return p["wall_s"]
    return None


def wallclock_section(rounds: int) -> dict:
    results = {name: _run_variant(name, rounds) for name in VARIANTS}
    # shared target: reachable by both sync and deadline, so time-to-target
    # compares the engines rather than who converged further
    target = TARGET_FRACTION * min(
        results["sync"]["final_accuracy"], results["deadline"]["final_accuracy"]
    )
    for name, res in results.items():
        res["time_to_target_s"] = _time_to(res["curve"], target)
    return {"target_accuracy": target, "variants": results}


def check_gates(section: dict) -> list:
    failures = []
    res = section["variants"]
    for name, r in res.items():
        if r["rounds"] == 0 or r["final_accuracy"] is None:
            failures.append(f"{name}: run did not complete")
    sync, dl = res["sync"], res["deadline"]
    t_sync, t_dl = sync["time_to_target_s"], dl["time_to_target_s"]
    if t_dl is None:
        failures.append("deadline: never reached the shared target accuracy")
    elif t_sync is not None and not t_dl < t_sync:
        failures.append(
            f"deadline time-to-target {t_dl:.2f}s not below synchronous {t_sync:.2f}s"
        )
    for name in ("deadline", "buffered"):
        gap = sync["final_accuracy"] - res[name]["final_accuracy"]
        if gap > ACC_FLOOR:
            failures.append(
                f"{name}: final accuracy {res[name]['final_accuracy']:.3f} is "
                f"{gap:.3f} below the synchronous baseline (floor {ACC_FLOOR})"
            )
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced rounds + hard gates (the CI chaos gate)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the round count (default 60, smoke 20)")
    ap.add_argument("--json", nargs="?", const="BENCH_throughput.json", default=None,
                    help="merge results into a bench JSON "
                    "(default path: BENCH_throughput.json)")
    args = ap.parse_args()
    rounds = args.rounds or (20 if args.smoke else 60)

    section = wallclock_section(rounds)
    print(f"target accuracy: {section['target_accuracy']:.3f}")
    for name, r in section["variants"].items():
        t = r["time_to_target_s"]
        print(
            f"  {name:9s} final_acc={r['final_accuracy']:.3f} "
            f"wall={r['final_wall_s']:8.2f}s "
            f"time_to_target={'never' if t is None else f'{t:8.2f}s'}"
        )

    if args.json:
        from benchmarks.common import merge_write_json

        merge_write_json(args.json, {"semisync": section})
        print(f"wrote semisync section -> {args.json}")

    if args.smoke:
        failures = check_gates(section)
        if failures:
            raise SystemExit("chaos gate FAILED:\n  " + "\n  ".join(failures))
        print("chaos gate OK: completes under dropout, deadline beats the "
              "barrier to target, accuracy within the floor")


if __name__ == "__main__":
    main()
