"""Paper Fig. 2: cloud-only vs edge-only vs hierarchical FL, accuracy vs time.

cloud-based : all 50 clients, aggregation every kappa=60 steps, 10× latency.
edge-based  : ONE edge's 10 clients only (limited data access), kappa=6.
hierarchical: 50 clients, kappa1=6, kappa2=10 (cloud every 60).
"""
import numpy as np

from benchmarks.common import build_problem, run_schedule
from repro.core import FedTopology, HierFAVGConfig, cost_model as cm
from repro.data import FederatedBatcher
from repro.fed import FederatedRunner, RunnerConfig
from repro.models import cnn
from repro.optim import exponential_decay, sgd
import jax


def run_edge_only(seed=0, rounds=60, class_sep=2.0):
    """Single-edge FL: the edge's 10 clients see only 1/5 of the data."""
    init, apply_fn, eval_fn, batcher_all, data = build_problem(
        seed=seed, partition="simple_niid", class_sep=class_sep
    )
    # restrict to edge 0's clients
    parts = batcher_all.client_indices[:10]
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=8, seed=seed
    )
    topo = FedTopology(num_edges=1, clients_per_edge=10)
    hier = HierFAVGConfig(kappa1=6, kappa2=1)
    costs = cm.WorkloadCosts(  # edge-only: no cloud hop
        t_comp=cm.paper_workload("mnist").t_comp,
        t_comm_edge=cm.paper_workload("mnist").t_comm_edge,
        e_comp=cm.paper_workload("mnist").e_comp,
        e_comm_edge=cm.paper_workload("mnist").e_comm_edge,
        cloud_latency_mult=1.0,
    )
    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(exponential_decay(0.15, 0.995, 50)),
        topology=topo, hier_config=hier,
        data_sizes=batcher.data_sizes, batcher=batcher,
        runner_config=RunnerConfig(num_rounds=rounds, eval_every=1),
        eval_fn=eval_fn, costs=costs,
    )
    state = runner.init(jax.random.PRNGKey(seed), init(jax.random.PRNGKey(seed + 1)))
    runner.run(state)
    return runner


ALPHA = 0.90
SEP = 2.0  # harder problem: time-to-accuracy differentiates topologies


def main(csv=True):
    from benchmarks.common import first_reach

    cloud = run_schedule(60, 1, partition="simple_niid", rounds=10, class_sep=SEP)
    hier = run_schedule(6, 10, partition="simple_niid", rounds=100, class_sep=SEP)
    edge = run_edge_only(class_sep=SEP)

    def stats(r):
        accs = [h.accuracy for h in r.history if h.accuracy is not None]
        hit = first_reach(r, ALPHA)
        return max(accs), (hit[1] if hit else float("inf"))

    rows = {}
    for name, r in (("cloud", cloud), ("hier", hier), ("edge_only", edge)):
        best_acc, t_alpha = stats(r)
        rows[name] = (best_acc, t_alpha)
        print(f"fig2_{name},best_acc={best_acc:.3f},T_{ALPHA}={t_alpha:.1f}s")
    # headline claims: hier reaches edge-level accuracy AND beats cloud's T_alpha
    print(
        f"fig2_claims,hier_acc_ge_edge={rows['hier'][0] >= rows['edge_only'][0] - 0.01},"
        f"hier_T_le_cloud={rows['hier'][1] <= rows['cloud'][1]}"
    )


if __name__ == "__main__":
    main()
