"""Paper Fig. 2: cloud-only vs edge-only vs hierarchical FL, accuracy vs time.

cloud-based : all 50 clients, aggregation every kappa=60 steps, 10× latency.
edge-based  : ONE edge's 10 clients only (limited data access), kappa=6.
hierarchical: 50 clients, kappa1=6, kappa2=10 (cloud every 60).
"""
from benchmarks.common import run_schedule
from repro.fed import scenarios


def run_edge_only(seed=0, rounds=60, class_sep=2.0):
    """Single-edge FL: the edge's 10 clients see only 1/5 of the data
    (the ``edge_only`` registry scenario: a 50-client partition restricted
    to the first edge, cloud_latency_mult=1)."""
    spec = scenarios.get("edge_only", overrides=[
        f"data.seed={seed}", f"run.seed={seed}", f"run.num_rounds={rounds}",
        f"data.class_sep={class_sep}",
    ])
    runner, _ = spec.run_experiment()
    return runner


ALPHA = 0.90
SEP = 2.0  # harder problem: time-to-accuracy differentiates topologies


def main(csv=True):
    from benchmarks.common import first_reach

    cloud = run_schedule(60, 1, partition="simple_niid", rounds=10, class_sep=SEP)
    hier = run_schedule(6, 10, partition="simple_niid", rounds=100, class_sep=SEP)
    edge = run_edge_only(class_sep=SEP)

    def stats(r):
        accs = [h.accuracy for h in r.history if h.accuracy is not None]
        hit = first_reach(r, ALPHA)
        return max(accs), (hit[1] if hit else float("inf"))

    rows = {}
    for name, r in (("cloud", cloud), ("hier", hier), ("edge_only", edge)):
        best_acc, t_alpha = stats(r)
        rows[name] = (best_acc, t_alpha)
        print(f"fig2_{name},best_acc={best_acc:.3f},T_{ALPHA}={t_alpha:.1f}s")
    # headline claims: hier reaches edge-level accuracy AND beats cloud's T_alpha
    print(
        f"fig2_claims,hier_acc_ge_edge={rows['hier'][0] >= rows['edge_only'][0] - 0.01},"
        f"hier_T_le_cloud={rows['hier'][1] <= rows['cloud'][1]}"
    )


if __name__ == "__main__":
    main()
