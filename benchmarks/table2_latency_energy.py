"""Paper Table II: T_alpha / E_alpha for the kappa sweeps.

IIa: MNIST-workload constants, edge-IID and edge-NIID partitions, alpha=0.85.
IIb: CIFAR-workload constants, simple-NIID partition, alpha=0.70.
Steps-to-accuracy are MEASURED on the synthetic stand-in; T/E use the
paper's Table I cost constants — the trade-off structure (T falls with
kappa2; E is U-shaped) is the reproduction target.

IIc [beyond paper]: the IIa edge-IID sweep rerun with an int8 cloud hop
(``fed.transport``) — same schedules, ~¼ the DCN bytes, so the T_alpha
accounting reflects the compressed wire.

``--sim`` [beyond paper] appends stochastic percentile rows: each IIa
point's T_alpha replayed by ``repro.sim`` under the ``congested_backhaul``
network (10% of edges 8x slower + lognormal jitter), scaling the measured
steps-to-accuracy by the round-time distribution. Rounds are treated as
perfectly correlated (one network world per trial scales every interval
alike) — a tail-heavy upper-bound reading, stated here once.
"""
from benchmarks.common import first_reach, run_schedule


def _sim_rows(k1, k2, steps, label, trials=200):
    """p50/p99 T_alpha under the congested-backhaul network."""
    import numpy as np

    from repro.fed import scenarios
    from repro.sim import simulate_spec

    spec = scenarios.get(
        "congested_backhaul", overrides=[f"schedule.kappas={k1},{k2}"]
    )
    res = simulate_spec(spec, trials=trials)
    n_intervals = steps / (k1 * k2)
    t_alpha = n_intervals * res.round_time
    p50, p99 = np.percentile(t_alpha, [50.0, 99.0])
    print(
        f"table2sim_{label}_k1={k1}_k2={k2},trials={trials},"
        f"T50={p50:.1f}s,T99={p99:.1f}s,tail_ratio={p99 / p50:.3f}"
    )
    return float(p50), float(p99)


def main(csv=True, sim=False, sim_trials=200):
    print("# Table IIa (mnist costs, alpha=0.85)")
    rows = []
    for dist in ("edge_iid", "edge_niid"):
        for k1, k2 in ((60, 1), (30, 2), (15, 4), (6, 10)):
            r = run_schedule(k1, k2, partition=dist, workload="mnist", rounds=360 // k1)
            hit = first_reach(r, 0.85)
            if hit is None:
                print(f"table2a_{dist}_k1={k1}_k2={k2},NOT_REACHED")
                continue
            steps, T, E = hit
            rows.append((dist, k1, k2, steps, T, E))
            print(f"table2a_{dist}_k1={k1}_k2={k2},steps={steps},T={T:.1f}s,E={E:.2f}J")
            if sim:
                _sim_rows(k1, k2, steps, dist, trials=sim_trials)

    print("# Table IIc (mnist costs, alpha=0.85, edge IID, int8 cloud hop)")
    for k1, k2 in ((30, 2), (15, 4), (6, 10)):
        r = run_schedule(k1, k2, partition="edge_iid", workload="mnist",
                         rounds=360 // k1, transport="identity/int8")
        hit = first_reach(r, 0.85)
        if hit is None:
            print(f"table2c_int8_k1={k1}_k2={k2},NOT_REACHED")
            continue
        steps, T, E = hit
        rows.append(("edge_iid_int8_cloud", k1, k2, steps, T, E))
        print(f"table2c_int8_k1={k1}_k2={k2},steps={steps},T={T:.1f}s,E={E:.2f}J")

    print("# Table IIb (cifar costs, alpha=0.70, simple NIID)")
    for k1, k2 in ((50, 1), (25, 2), (10, 5), (5, 10)):
        r = run_schedule(k1, k2, partition="simple_niid", workload="cifar10", rounds=300 // k1)
        hit = first_reach(r, 0.70)
        if hit is None:
            print(f"table2b_k1={k1}_k2={k2},NOT_REACHED")
            continue
        steps, T, E = hit
        print(f"table2b_k1={k1}_k2={k2},steps={steps},T={T:.0f}s,E={E:.0f}J")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", action="store_true",
                    help="append stochastic T_alpha percentile rows (repro.sim)")
    ap.add_argument("--sim-trials", type=int, default=200)
    args = ap.parse_args()
    main(sim=args.sim, sim_trials=args.sim_trials)
