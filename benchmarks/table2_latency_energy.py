"""Paper Table II: T_alpha / E_alpha for the kappa sweeps.

IIa: MNIST-workload constants, edge-IID and edge-NIID partitions, alpha=0.85.
IIb: CIFAR-workload constants, simple-NIID partition, alpha=0.70.
Steps-to-accuracy are MEASURED on the synthetic stand-in; T/E use the
paper's Table I cost constants — the trade-off structure (T falls with
kappa2; E is U-shaped) is the reproduction target.

IIc [beyond paper]: the IIa edge-IID sweep rerun with an int8 cloud hop
(``fed.transport``) — same schedules, ~¼ the DCN bytes, so the T_alpha
accounting reflects the compressed wire.
"""
from benchmarks.common import first_reach, run_schedule


def main(csv=True):
    print("# Table IIa (mnist costs, alpha=0.85)")
    rows = []
    for dist in ("edge_iid", "edge_niid"):
        for k1, k2 in ((60, 1), (30, 2), (15, 4), (6, 10)):
            r = run_schedule(k1, k2, partition=dist, workload="mnist", rounds=360 // k1)
            hit = first_reach(r, 0.85)
            if hit is None:
                print(f"table2a_{dist}_k1={k1}_k2={k2},NOT_REACHED")
                continue
            steps, T, E = hit
            rows.append((dist, k1, k2, steps, T, E))
            print(f"table2a_{dist}_k1={k1}_k2={k2},steps={steps},T={T:.1f}s,E={E:.2f}J")

    print("# Table IIc (mnist costs, alpha=0.85, edge IID, int8 cloud hop)")
    for k1, k2 in ((30, 2), (15, 4), (6, 10)):
        r = run_schedule(k1, k2, partition="edge_iid", workload="mnist",
                         rounds=360 // k1, transport="identity/int8")
        hit = first_reach(r, 0.85)
        if hit is None:
            print(f"table2c_int8_k1={k1}_k2={k2},NOT_REACHED")
            continue
        steps, T, E = hit
        rows.append(("edge_iid_int8_cloud", k1, k2, steps, T, E))
        print(f"table2c_int8_k1={k1}_k2={k2},steps={steps},T={T:.1f}s,E={E:.2f}J")

    print("# Table IIb (cifar costs, alpha=0.70, simple NIID)")
    for k1, k2 in ((50, 1), (25, 2), (10, 5), (5, 10)):
        r = run_schedule(k1, k2, partition="simple_niid", workload="cifar10", rounds=300 // k1)
        hit = first_reach(r, 0.70)
        if hit is None:
            print(f"table2b_k1={k1}_k2={k2},NOT_REACHED")
            continue
        steps, T, E = hit
        print(f"table2b_k1={k1}_k2={k2},steps={steps},T={T:.0f}s,E={E:.0f}J")
    return rows


if __name__ == "__main__":
    main()
