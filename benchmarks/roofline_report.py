"""§Roofline: aggregate artifacts/dryrun into the per-cell table.

Reads the dry-run JSONs (single-pod for the roofline table per
instructions; multi-pod rows shown for the pod-axis traffic) and prints the
three terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the
amortized HierFAVG step where phase artifacts exist.
"""
import glob
import json
import os


def load(out_dir="artifacts/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "mesh" in rec and "roofline" in rec:  # skip auxiliary artifacts
            cells.append(rec)
    return cells


def fmt_row(c):
    r = c["roofline"]
    amort = c.get("phases", {}).get("amortized_step")
    extra = ""
    if amort:
        extra = f",amortized_coll_ms={amort['collective_s']*1e3:.1f}"
    return (
        f"roofline,{c['arch']},{c['shape']},{c['mesh']},"
        f"compute_ms={r['compute_s']*1e3:.2f},memory_ms={r['memory_s']*1e3:.2f},"
        f"collective_ms={r['collective_s']*1e3:.2f},dominant={r['dominant']},"
        f"useful_flops_ratio={r['useful_flops_ratio']:.3f},"
        f"roofline_fraction={r['roofline_fraction']:.4f}{extra}"
    )


def main(csv=True, out_dir="artifacts/dryrun"):
    cells = load(out_dir)
    if not cells:
        print("roofline_report,NO_ARTIFACTS (run: python -m repro.launch.dryrun)")
        return
    single = [c for c in cells if "single" in c["mesh"]]
    multi = [c for c in cells if "multi" in c["mesh"]]
    print(f"# roofline table: {len(single)} single-pod cells, {len(multi)} multi-pod cells")
    for c in single:
        print(fmt_row(c))
    print("# multi-pod (pod axis = DCN)")
    for c in multi:
        r = c["roofline"]
        dcn = sum(v for k, v in r["coll_breakdown"].items() if "pod" in k)
        print(
            f"multipod,{c['arch']},{c['shape']},dcn_GB_per_dev={dcn/1e9:.3f},"
            f"dominant={r['dominant']}"
        )


if __name__ == "__main__":
    main()
