"""HierFAVG communication scaling: the paper's amortization knob in bytes,
for uniform, ragged, and deeper-than-two hierarchies.

Analytic per-step link traffic (ring model, dist.collectives) for the
production meshes as a function of the per-level κ schedule — shows how the
hierarchy moves traffic from the expensive (DCN) link to the cheap (ICI)
link, what int8 delta compression buys on top, and where the bottleneck
edge sits when the fan-out is ragged.

    python benchmarks/aggregation_scaling.py                 # default sweep
    python benchmarks/aggregation_scaling.py --levels 3      # uniform 3-level
    python benchmarks/aggregation_scaling.py \
        --fanout 16,12,10,7,5/3,2/2 --kappas 16,2,2          # explicit tree
"""
import argparse

from repro.configs.base import param_count
from repro.configs.registry import get_config
from repro.core.hierarchy import HierarchySpec, parse_fanouts
from repro.dist.collectives import hierarchy_traffic_per_step

ARCHS = ("granite-3-2b", "yi-9b", "deepseek-7b")

# default sweep: the seed's two-level (8 edges x 4 clients) plus a ragged
# two-level and uniform/ragged three-level variant of the same 32 clients
SWEEP = {
    2: (
        ("uniform", HierarchySpec.uniform(8, 4), ((1, 1), (16, 1), (16, 4), (64, 4))),
        ("ragged", parse_fanouts("8,6,6,4,3,2,2,1/8"), ((16, 4), (64, 4))),
    ),
    3: (
        ("uniform", parse_fanouts("4,4,4,4,4,4,4,4/4,4/2"), ((16, 2, 2), (64, 2, 2))),
        ("ragged", parse_fanouts("8,6,6,4,3,2,2,1/5,3/2"), ((16, 2, 2), (64, 2, 2))),
    ),
}


def report(arch: str, shape: str, spec: HierarchySpec, kappas, per_dev: float) -> None:
    per_level = hierarchy_traffic_per_step(per_dev, spec, kappas)
    cells = ",".join(
        f"L{i+1}_MBps_per_step={b / 1e6:.2f}" for i, b in enumerate(per_level)
    )
    cloud = per_level[-1]
    kstr = "x".join(str(k) for k in kappas)
    print(
        f"agg_scaling_{arch}_{shape}_{spec.describe().split()[0]}_k={kstr},"
        f"{cells},cloud_int8={cloud / 4 / 1e6:.3f}"
    )


def main(argv=None, csv=True):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--levels", type=int, default=0,
                    help="restrict the sweep to trees of this depth (0 = all)")
    ap.add_argument("--fanout", type=str, default=None,
                    help="explicit bottom-up fan-out, e.g. 16,12,10,7,5/3,2/2")
    ap.add_argument("--kappas", type=str, default=None,
                    help="per-level schedule for --fanout, e.g. 16,2,2")
    ap.add_argument("--archs", type=str, default=",".join(ARCHS))
    # tolerate the harness's own flags when driven by benchmarks.run
    args, _ = ap.parse_known_args(argv)

    if args.fanout:
        spec = parse_fanouts(args.fanout)
        if args.kappas:
            kappas = tuple(int(k) for k in args.kappas.split(","))
        else:
            kappas = (16,) + (2,) * (spec.depth - 1)
        sweep = {spec.depth: (("custom", spec, (kappas,)),)}
    else:
        if args.kappas:
            ap.error("--kappas needs --fanout (the default sweep fixes its own schedules)")
        sweep = {d: v for d, v in SWEEP.items() if not args.levels or d == args.levels}

    for arch in args.archs.split(","):
        cfg = get_config(arch)
        pbytes = param_count(cfg) * 2  # bf16
        per_dev = pbytes / 16  # TP-sharded within a client group
        for depth in sorted(sweep):
            for shape, spec, kappa_list in sweep[depth]:
                for kappas in kappa_list:
                    report(arch, shape, spec, kappas, per_dev)

    # headline: (16,4) vs (1,1) cloud-traffic reduction on the seed topology
    cfg = get_config("granite-3-2b")
    per_dev = param_count(cfg) * 2 / 16
    uni = HierarchySpec.uniform(8, 4)
    c11 = hierarchy_traffic_per_step(per_dev, uni, (1, 1))[-1]
    c164 = hierarchy_traffic_per_step(per_dev, uni, (16, 4))[-1]
    print(
        f"agg_scaling_headline,cloud_traffic_reduction={c11 / c164:.0f}x,"
        f"with_int8={4 * c11 / c164:.0f}x"
    )


if __name__ == "__main__":
    main()
