"""HierFAVG communication scaling: the paper's amortization knob in bytes.

Analytic per-step link traffic (ring model) for the production meshes as a
function of (kappa1, kappa2), plus the compressed-cloud-hop variant — shows
how the hierarchy moves traffic from the expensive (DCN) link to the cheap
(ICI) link, and what int8 delta compression buys on top.
"""
from repro.configs.registry import get_config
from repro.configs.base import param_count
from repro.dist.collectives import hierfavg_traffic_per_step


def main(csv=True):
    for arch in ("granite-3-2b", "yi-9b", "deepseek-7b"):
        cfg = get_config(arch)
        pbytes = param_count(cfg) * 2  # bf16
        per_dev = pbytes / 16  # TP-sharded within a client group
        for k1, k2 in ((1, 1), (16, 1), (16, 4), (64, 4)):
            edge, cloud = hierfavg_traffic_per_step(
                per_dev, clients_per_edge=4, num_edges=8, kappa1=k1, kappa2=k2
            )
            print(
                f"agg_scaling_{arch}_k1={k1}_k2={k2},"
                f"edge_MBps_per_step={edge/1e6:.1f},cloud_MBps_per_step={cloud/1e6:.1f},"
                f"cloud_int8={cloud/4/1e6:.2f}"
            )
    # headline: (16,4) vs (1,1) cloud-traffic reduction
    cfg = get_config("granite-3-2b")
    per_dev = param_count(cfg) * 2 / 16
    _, c11 = hierfavg_traffic_per_step(per_dev, 4, 8, 1, 1)
    _, c164 = hierfavg_traffic_per_step(per_dev, 4, 8, 16, 4)
    print(f"agg_scaling_headline,cloud_traffic_reduction={(c11/c164):.0f}x,with_int8={(4*c11/c164):.0f}x")


if __name__ == "__main__":
    main()
