"""Shared harness for the paper-reproduction benches.

The paper's experiments are MNIST/CIFAR CNNs on 50 clients / 5 edges. The
offline stand-in keeps the exact topology and partition protocols with the
synthetic 10-class dataset (data.synthetic) and a small MLP — the
communication/computation COST model still uses the paper's Table I
constants, so T_alpha/E_alpha accounting is the paper's.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedTopology, HierFAVGConfig, cost_model as cm
from repro.data import FederatedBatcher, clustered_gaussians, make_partition, partition_hierarchy
from repro.fed import FederatedRunner, RunnerConfig, TransportSpec
from repro.models import cnn
from repro.optim import exponential_decay, sgd


def build_problem(seed=0, partition="edge_iid", num_clients=50, num_edges=5,
                  num_samples=3000, dim=16, class_sep=3.5, spec=None):
    """``spec`` (a HierarchySpec) switches the partition to the ragged tree;
    otherwise the uniform (num_edges, num_clients) split applies."""
    rng = np.random.default_rng(seed)
    data = clustered_gaussians(
        rng, num_samples=num_samples, num_classes=10, dim=(dim,), class_sep=class_sep
    )
    if spec is not None:
        parts = partition_hierarchy(partition, data.y, spec, rng)
    else:
        parts = make_partition(partition, data.y, num_edges, num_clients // num_edges, rng)
    batcher = FederatedBatcher(
        {"inputs": data.x, "targets": data.y}, parts, batch_size=8, seed=seed
    )

    def init(rng_key):
        k1, k2 = jax.random.split(rng_key)
        return {
            "w1": jax.random.normal(k1, (dim, 48)) * 0.25,
            "b1": jnp.zeros((48,)),
            "w2": jax.random.normal(k2, (48, 10)) * 0.25,
            "b2": jnp.zeros((10,)),
        }

    def apply_fn(p, x):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def eval_fn(p):
        return float(cnn.accuracy(apply_fn(p, jnp.asarray(data.x)), jnp.asarray(data.y)))

    return init, apply_fn, eval_fn, batcher, data


def run_schedule(kappa1, kappa2, *, partition="edge_iid", rounds=None, seed=0,
                 workload="mnist", eval_every=1, lr=0.15, class_sep=3.5,
                 transport=None):
    """Train one (kappa1, kappa2) schedule; returns the runner (history has
    loss/accuracy/T/E per round). ``transport`` (a ``fed.transport.
    TransportSpec`` or codec string like 'identity/int8') compresses the
    uplinks; T/E/wire accounting then reflects the compressed bytes."""
    if isinstance(transport, str):
        transport = TransportSpec.parse(transport)
    init, apply_fn, eval_fn, batcher, _ = build_problem(
        seed=seed, partition=partition, class_sep=class_sep
    )
    topo = FedTopology(num_edges=5, clients_per_edge=10)
    hier = HierFAVGConfig(kappa1=kappa1, kappa2=kappa2, transport=transport)
    if rounds is None:
        rounds = max(240 // kappa1, 6)
    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(exponential_decay(lr, 0.995, 50)),
        topology=topo,
        hier_config=hier,
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=rounds, eval_every=eval_every),
        eval_fn=eval_fn,
        costs=cm.paper_workload(workload),
    )
    state = runner.init(jax.random.PRNGKey(seed), init(jax.random.PRNGKey(seed + 1)))
    runner.run(state)
    return runner


def run_hierarchy_schedule(spec, kappas, *, partition="edge_iid", rounds=None, seed=0,
                           workload="mnist", eval_every=1, lr=0.15, class_sep=3.5,
                           transport=None):
    """Train one κ-vector schedule on an arbitrary (possibly ragged)
    HierarchySpec; returns the runner. The two-level uniform call is
    equivalent to ``run_schedule`` on the matching FedTopology."""
    if isinstance(transport, str):
        transport = TransportSpec.parse(transport)
    init, apply_fn, eval_fn, batcher, _ = build_problem(
        seed=seed, partition=partition, class_sep=class_sep, spec=spec
    )
    hier = HierFAVGConfig.multi_level(kappas, transport=transport)
    if rounds is None:
        rounds = max(240 // hier.kappa1, 6)
    runner = FederatedRunner(
        loss_fn=cnn.make_cnn_loss_fn(apply_fn),
        optimizer=sgd(exponential_decay(lr, 0.995, 50)),
        topology=spec,
        hier_config=hier,
        data_sizes=batcher.data_sizes,
        batcher=batcher,
        runner_config=RunnerConfig(num_rounds=rounds, eval_every=eval_every),
        eval_fn=eval_fn,
        costs=cm.paper_workload(workload),
    )
    state = runner.init(jax.random.PRNGKey(seed), init(jax.random.PRNGKey(seed + 1)))
    runner.run(state)
    return runner


def first_reach(runner, alpha):
    """(steps, T, E) when accuracy first reached alpha; None if never."""
    for h in runner.history:
        if h.accuracy is not None and h.accuracy >= alpha:
            return h.step, h.sim_time_s, h.sim_energy_j
    return None


def timed(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out
