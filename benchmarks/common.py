"""Shared harness for the paper-reproduction benches — thin wrappers over
the declarative ``repro.fed.api`` spec layer.

The paper's experiments are MNIST/CIFAR CNNs on 50 clients / 5 edges. The
offline stand-in keeps the exact topology and partition protocols with the
synthetic 10-class dataset (data.synthetic) and a small MLP — the
communication/computation COST model still uses the paper's Table I
constants, so T_alpha/E_alpha accounting is the paper's. Every helper here
assembles an ``ExperimentSpec`` and calls ``run_experiment()``; the paper
benches no longer hand-wire ``FederatedRunner(...)`` constructors.
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.fed.api import (
    AggregatorSpec,
    CostSpec,
    DataSpec,
    ExperimentSpec,
    ModelSpec,
    RunSpec,
    ScheduleSpec,
    TopologySpec,
    TransportSpec,
)


def _levels_text(spec_or_text, default: str) -> str:
    """Accept a codec/aggregator string or a built per-level spec object."""
    if spec_or_text is None:
        return default
    if isinstance(spec_or_text, str):
        return spec_or_text
    return spec_or_text.describe()


def bench_spec(kappa1, kappa2, *, partition="edge_iid", rounds=None, seed=0,
               workload="mnist", eval_every=1, lr=0.15, class_sep=3.5,
               transport=None, aggregators=None, fanouts="", kappas=None) -> ExperimentSpec:
    """The benchmark stand-in problem as a spec: 50 clients / 5 edges (or
    the ``fanouts`` tree), exponential-decay SGD, paper cost constants."""
    kv = tuple(kappas) if kappas is not None else (kappa1, kappa2)
    if rounds is None:
        rounds = max(240 // kv[0], 6)
    return ExperimentSpec(
        name=f"bench_k{'_'.join(map(str, kv))}_{partition}",
        topology=TopologySpec(fanouts=fanouts) if fanouts
        else TopologySpec(num_edges=5, clients_per_edge=10),
        schedule=ScheduleSpec(kappas=kv),
        data=DataSpec(partition=partition, class_sep=class_sep, seed=seed),
        model=ModelSpec(lr=lr, lr_schedule="exponential"),
        transport=TransportSpec(levels=_levels_text(transport, "identity")),
        aggregators=AggregatorSpec(levels=_levels_text(aggregators, "weighted_mean")),
        cost=CostSpec(workload=workload),
        run=RunSpec(num_rounds=rounds, eval_every=eval_every, seed=seed),
    )


def run_schedule(kappa1, kappa2, *, partition="edge_iid", rounds=None, seed=0,
                 workload="mnist", eval_every=1, lr=0.15, class_sep=3.5,
                 transport=None, aggregators=None):
    """Train one (kappa1, kappa2) schedule; returns the runner (history has
    loss/accuracy/T/E per round). ``transport`` (a ``fed.transport.
    TransportSpec`` or codec string like 'identity/int8') compresses the
    uplinks; ``aggregators`` (a ``core.aggregation.AggregatorSpec`` or
    string like 'trimmed_mean:0.1/weighted_mean') swaps the per-level
    aggregation statistic."""
    spec = bench_spec(
        kappa1, kappa2, partition=partition, rounds=rounds, seed=seed,
        workload=workload, eval_every=eval_every, lr=lr, class_sep=class_sep,
        transport=transport, aggregators=aggregators,
    )
    runner, _ = spec.run_experiment()
    return runner


def run_hierarchy_schedule(spec, kappas, *, partition="edge_iid", rounds=None, seed=0,
                           workload="mnist", eval_every=1, lr=0.15, class_sep=3.5,
                           transport=None, aggregators=None):
    """Train one κ-vector schedule on an arbitrary (possibly ragged)
    HierarchySpec; returns the runner. The two-level uniform call is
    equivalent to ``run_schedule`` on the matching FedTopology."""
    exp = bench_spec(
        kappas[0], kappas[1] if len(kappas) > 1 else 1, kappas=tuple(kappas),
        fanouts=spec.fanouts_text(), partition=partition, rounds=rounds,
        seed=seed, workload=workload, eval_every=eval_every, lr=lr,
        class_sep=class_sep, transport=transport, aggregators=aggregators,
    )
    runner, _ = exp.run_experiment()
    return runner


def first_reach(runner, alpha):
    """(steps, T, E) when accuracy first reached alpha; None if never."""
    for h in runner.history:
        if h.accuracy is not None and h.accuracy >= alpha:
            return h.step, h.sim_time_s, h.sim_energy_j
    return None


def merge_write_json(path, results, *, skip_empty=()):
    """Merge-preserving bench JSON write: load the existing file (if any),
    overwrite only the keys in ``results``, keep everything else. A key
    named in ``skip_empty`` whose new value is falsy keeps its previously
    recorded value — partial runs (``--smoke``, single-section reruns)
    must not clobber another bench family's sweep. Returns the merged
    dict as written."""
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    if not isinstance(merged, dict):
        merged = {}
    for key, val in results.items():
        if key in skip_empty and not val and key in merged:
            continue
        merged[key] = val
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
    return merged


def timed(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out
